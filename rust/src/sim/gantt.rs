//! Gantt-chart rendering of simulated timelines — regenerates the paper's
//! Figures 2, 3, 4, 6 and 7 as ASCII (for the terminal) and CSV (for
//! plotting).
//!
//! For anything beyond a quick terminal glance, `dash timeline` renders
//! the same spans — via the typed trace layer ([`crate::trace`]) — as an
//! interactive, self-contained HTML page with per-SM lanes, hover detail
//! and a schedule-diff mode; this module stays as the thin ASCII wrapper.

use super::engine::{LinkSpan, TaskSpan};

/// Render an ASCII Gantt chart. Each row is an SM; `c`/`r` segments are
/// labelled with the Q-tile index, stalls with `.`. `width` is the chart
/// width in characters (time is scaled to fit).
pub fn render_gantt(spans: &[TaskSpan], n_sm: usize, width: usize) -> String {
    if spans.is_empty() {
        return "(empty timeline)".to_string();
    }
    let t_end = spans.iter().map(|s| s.reduce_end).fold(0.0f64, f64::max);
    if t_end <= 0.0 {
        // Every span is zero-length (e.g. a zero-cost model): `width / 0`
        // would make the scale inf and every painted index NaN. Render an
        // empty chart instead.
        let mut out = String::from(
            "t = 0 .. 0 cycles (all spans zero-length — nothing to paint)\n",
        );
        for sm in 0..n_sm {
            out.push_str(&format!("SM{sm:<3}|{}|\n", " ".repeat(width)));
        }
        return out;
    }
    let scale = width as f64 / t_end;
    let mut rows = vec![vec![' '; width]; n_sm];

    let paint = |row: &mut [char], a: f64, b: f64, ch: char| {
        let i0 = ((a * scale) as usize).min(width.saturating_sub(1));
        let i1 = ((b * scale) as usize).clamp(i0 + 1, width);
        for c in row[i0..i1].iter_mut() {
            *c = ch;
        }
    };

    for s in spans {
        if s.sm >= n_sm {
            continue;
        }
        let q_char = char::from_digit((s.q % 36) as u32, 36).unwrap_or('#');
        // Compute segment (covers any reduction-stall gap too — the SM is
        // occupied either way), then the reduce segment.
        paint(&mut rows[s.sm], s.compute_start, s.reduce_start, q_char);
        paint(&mut rows[s.sm], s.reduce_start, s.reduce_end, '▒');
    }

    let mut out = String::new();
    out.push_str(&format!("t = 0 .. {t_end:.0} cycles  ('0-9a-z' = compute on that Q tile, '▒' = reduce)\n"));
    for (sm, row) in rows.iter().enumerate() {
        out.push_str(&format!("SM{sm:<3}|"));
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out
}

/// Lane labels for a multi-device timeline: one `dev<d>/sm<local>` label
/// per execution lane of each device, followed by one `link<i>` label per
/// interconnect link. Shared between the ASCII renderer and the trace
/// layer so `dash gantt` and `dash timeline` name lanes identically.
pub fn cluster_lane_labels(n_devices: usize, lanes_per_dev: usize, n_links: usize) -> Vec<String> {
    let mut labels = Vec::with_capacity(n_devices * lanes_per_dev + n_links);
    for d in 0..n_devices {
        for s in 0..lanes_per_dev {
            labels.push(format!("dev{d}/sm{s}"));
        }
    }
    for l in 0..n_links {
        labels.push(format!("link{l}"));
    }
    labels
}

/// Render an ASCII Gantt chart of a multi-device timeline: one row per
/// labelled lane (device-namespaced SM lanes, then interconnect links).
/// Compute/reduce segments paint like [`render_gantt`]; cross-device
/// transfer segments paint as `=` on the link rows.
pub fn render_gantt_cluster(
    spans: &[TaskSpan],
    links: &[LinkSpan],
    labels: &[String],
    width: usize,
) -> String {
    if spans.is_empty() {
        return "(empty timeline)".to_string();
    }
    let lanes_per_link: usize = labels.iter().filter(|l| !l.starts_with("link")).count();
    let t_end = spans
        .iter()
        .map(|s| s.reduce_end)
        .chain(links.iter().map(|l| l.t_end))
        .fold(0.0f64, f64::max);
    let pad = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    if t_end <= 0.0 {
        let mut out = String::from(
            "t = 0 .. 0 cycles (all spans zero-length — nothing to paint)\n",
        );
        for label in labels {
            out.push_str(&format!("{label:<pad$}|{}|\n", " ".repeat(width)));
        }
        return out;
    }
    let scale = width as f64 / t_end;
    let mut rows = vec![vec![' '; width]; labels.len()];

    let paint = |row: &mut [char], a: f64, b: f64, ch: char| {
        let i0 = ((a * scale) as usize).min(width.saturating_sub(1));
        let i1 = ((b * scale) as usize).clamp(i0 + 1, width);
        for c in row[i0..i1].iter_mut() {
            *c = ch;
        }
    };

    for s in spans {
        if s.sm >= rows.len() {
            continue;
        }
        let q_char = char::from_digit((s.q % 36) as u32, 36).unwrap_or('#');
        paint(&mut rows[s.sm], s.compute_start, s.reduce_start, q_char);
        paint(&mut rows[s.sm], s.reduce_start, s.reduce_end, '▒');
    }
    for l in links {
        let lane = lanes_per_link + l.link;
        if lane >= rows.len() {
            continue;
        }
        paint(&mut rows[lane], l.t_start, l.t_end, '=');
    }

    let mut out = String::new();
    out.push_str(&format!(
        "t = 0 .. {t_end:.0} cycles  ('0-9a-z' = compute on that Q tile, '▒' = reduce, '=' = transfer)\n"
    ));
    for (lane, row) in rows.iter().enumerate() {
        let label = labels.get(lane).map(String::as_str).unwrap_or("?");
        out.push_str(&format!("{label:<pad$}|"));
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out
}

/// Render a CSV of task spans: `sm,chain,head,kv,q,compute_start,reduce_start,reduce_end`.
pub fn render_gantt_csv(spans: &[TaskSpan]) -> String {
    let mut out = String::from("sm,chain,head,kv,q,compute_start,reduce_start,reduce_end\n");
    for s in spans {
        out.push_str(&format!(
            "{},{},{},{},{},{:.3},{:.3},{:.3}\n",
            s.sm, s.chain, s.head, s.kv, s.q, s.compute_start, s.reduce_start, s.reduce_end
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, MaskSpec, ProblemSpec};
    use crate::sim::{simulate, SimConfig};

    fn spans() -> Vec<TaskSpan> {
        let mut cfg = SimConfig::ideal(4);
        cfg.record_spans = true;
        simulate(&fa3(&ProblemSpec::square(4, 1, MaskSpec::causal()), true), &cfg)
            .unwrap()
            .spans
    }

    #[test]
    fn ascii_has_one_row_per_sm() {
        let g = render_gantt(&spans(), 4, 80);
        assert_eq!(g.lines().count(), 5); // header + 4 SMs
        assert!(g.contains("SM0"));
    }

    #[test]
    fn csv_has_header_and_all_tasks() {
        let s = spans();
        let csv = render_gantt_csv(&s);
        assert_eq!(csv.lines().count(), s.len() + 1);
        assert!(csv.starts_with("sm,chain,head,kv,q"));
    }

    #[test]
    fn cluster_labels_namespace_devices_then_links() {
        let labels = cluster_lane_labels(2, 3, 2);
        assert_eq!(
            labels,
            ["dev0/sm0", "dev0/sm1", "dev0/sm2", "dev1/sm0", "dev1/sm1", "dev1/sm2",
             "link0", "link1"]
        );
    }

    #[test]
    fn cluster_chart_paints_transfer_rows() {
        use crate::schedule::{ring, ScheduleKind};
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 2).unwrap();
        let mut cfg = SimConfig::ideal(8);
        cfg.record_spans = true;
        let r = simulate(&s, &cfg).unwrap();
        let labels = cluster_lane_labels(2, 8, 2);
        let g = render_gantt_cluster(&r.spans, &r.links, &labels, 80);
        assert_eq!(g.lines().count(), 19); // header + 16 SM lanes + 2 links
        assert!(g.contains("dev1/sm7") && g.contains("link1"));
        let link_row = g.lines().find(|l| l.starts_with("link0")).unwrap();
        assert!(link_row.contains('='), "transfer bar missing: {link_row}");
    }

    #[test]
    fn empty_timeline_ok() {
        assert_eq!(render_gantt(&[], 4, 80), "(empty timeline)");
    }

    #[test]
    fn all_zero_length_spans_render_an_empty_chart() {
        // Regression: t_end == 0 made `scale` infinite and painted NaN
        // indices. The chart must stay finite and well-formed.
        let zero = TaskSpan {
            sm: 0,
            chain: 0,
            head: 0,
            kv: 0,
            q: 1,
            compute_start: 0.0,
            compute_end: 0.0,
            ready: 0.0,
            reduce_start: 0.0,
            reduce_end: 0.0,
            l2_wait: 0.0,
        };
        let g = render_gantt(&[zero, TaskSpan { sm: 1, ..zero }], 2, 40);
        assert_eq!(g.lines().count(), 3); // header + 2 SM rows
        assert!(g.contains("SM0") && g.contains("SM1"));
        assert!(!g.contains("NaN") && !g.contains("inf"));
    }
}
