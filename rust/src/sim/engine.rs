//! Discrete-event execution engine.
//!
//! Models `n_sm` SMs executing a [`Schedule`]'s chains. Each SM runs chains
//! serially (persistent-CTA semantics: a chain, once started, occupies its
//! SM until done — stalls are *not* masked by switching chains, exactly the
//! hardware behaviour that makes deterministic reductions expensive).
//! Chains are taken from the launch-ordered grid queue, except pinned
//! chains which run on their designated SM.
//!
//! Per task `(head, kv, q)`:
//! 1. compute for `c * compute_scale * spill_factor`;
//! 2. if the chain is `ordered`, wait until every earlier contribution in
//!    `reduction_order[(head, q)]` has been folded, plus the L2 signalling
//!    latency from the SM that folded the previous contribution;
//! 3. reduce for `r * reduce_scale`, then release the next contributor.
//!
//! The makespan of a fully-pinned schedule equals the critical path of the
//! DAG built by [`crate::dag::build_schedule_dag`] with the same costs — an
//! invariant pinned by integration tests.
//!
//! # Hot path
//!
//! The engine is the inner loop of autotune search, the figure sweeps, and
//! `dash verify` — it runs thousands of times per workload. Two entry
//! points serve that load:
//!
//! * [`Simulator`] owns every working buffer (position tables, token
//!   semaphores, per-SM queues and FIFOs, the event heap, span storage) and
//!   *clears instead of frees* between [`Simulator::run`] calls, so a
//!   repeated-simulation loop allocates only on its first iteration (or
//!   when a larger problem grows a buffer). Results are bitwise-identical
//!   to a fresh run: every buffer is reset to its initial state at the
//!   start of `run`, never left to carry state across calls.
//! * [`simulate`] is a thin wrapper (fresh `Simulator` per call) so
//!   existing call sites work unchanged; [`simulate_batch`] fans a slice of
//!   schedules across host cores with one reused `Simulator` per worker,
//!   returning results in input order regardless of thread count.

use super::l2::L2Model;
use crate::schedule::Schedule;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Cost model for one simulated kernel launch.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Base compute cost per tile, in cycles (`c`).
    pub compute: f64,
    /// Base global-reduction cost per tile, in cycles (`r`).
    pub reduce: f64,
    /// Register-spill compute inflation (>= 1.0), from
    /// [`super::regpressure::RegisterModel::spill_factor`].
    pub spill_factor: f64,
    /// Inter-SM signalling latency model.
    pub l2: L2Model,
}

impl Default for CostModel {
    fn default() -> Self {
        Self { compute: 1.0, reduce: 0.25, spill_factor: 1.0, l2: L2Model::ideal() }
    }
}

impl CostModel {
    /// Reject non-finite cost fields up front. A NaN or infinite cost
    /// would otherwise poison every timestamp in the event heap; the
    /// engine refuses it with a typed error instead of simulating garbage.
    pub fn validate(&self) -> Result<(), SimError> {
        let fields = [
            ("compute", self.compute),
            ("reduce", self.reduce),
            ("spill_factor", self.spill_factor),
            ("l2.local_latency", self.l2.local_latency),
            ("l2.remote_latency", self.l2.remote_latency),
        ];
        for (field, value) in fields {
            if !value.is_finite() {
                return Err(SimError::NonFiniteCost { field, value });
            }
        }
        Ok(())
    }
}

/// Simulator configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Number of SMs (from the active [`crate::hw::GpuProfile`] — e.g.
    /// 132 on the `h800` preset; the paper's abstract model uses `n_kv`).
    pub n_sm: usize,
    /// Costs and hardware effects.
    pub cost: CostModel,
    /// Record per-task spans for Gantt rendering (disable for sweeps).
    pub record_spans: bool,
    /// dQ-writer pipeline depth: how many computed-but-unreduced tiles an
    /// SM may have in flight before its compute stalls.
    ///
    /// * `0` — synchronous: each tile's reduction sits on the SM's serial
    ///   path, exactly the paper's §3 Gantt model (its closed forms hold).
    /// * `2` — the FA3 implementation: a separate dQ-writer warp drains an
    ///   s-stage circular SMEM buffer (Algorithm 1 lines 30-36), so compute
    ///   runs ahead until the buffer fills. Used by the figure harness.
    pub writer_depth: usize,
    /// Co-resident CTAs per SM. The FA3 backward runs 2 CTAs/SM at
    /// headdim 64 (its SMEM footprint allows it) and 1 at headdim 128;
    /// co-residency masks reduction stalls because the partner CTA keeps
    /// the SM busy. Modelled as `occupancy` independent execution slots
    /// per SM, each computing at `1/occupancy` rate.
    pub occupancy: usize,
    /// Identity of the [`crate::hw::GpuProfile`] the costs above were
    /// derived from (`GpuProfile::fingerprint`), folded into the autotune
    /// cache key so schedules tuned for one part never serve another.
    /// `0` = hand-specified abstract costs (no hardware identity).
    pub hw_fingerprint: u64,
}

impl SimConfig {
    /// The paper's idealized abstract machine: `n` SMs, unit costs,
    /// synchronous reductions (§3 model — closed forms hold exactly).
    pub fn ideal(n_sm: usize) -> Self {
        Self {
            n_sm,
            cost: CostModel::default(),
            record_spans: false,
            writer_depth: 0,
            occupancy: 1,
            hw_fingerprint: 0,
        }
    }

    /// FA3-realistic pipeline: async dQ-writer of depth 2, co-residency
    /// per head dimension (2 CTAs/SM at hd <= 64, 1 at hd 128). Callers
    /// with a concrete [`crate::hw::GpuProfile`] should stamp
    /// `hw_fingerprint` afterwards (see [`crate::hw::Machine::sim_config`]).
    pub fn fa3_pipeline(n_sm: usize, cost: CostModel, occupancy: usize) -> Self {
        Self {
            n_sm,
            cost,
            record_spans: false,
            writer_depth: 2,
            occupancy: occupancy.max(1),
            hw_fingerprint: 0,
        }
    }
}

/// One executed task, for Gantt charts and the trace layer
/// ([`crate::trace`]).
///
/// The five timestamps decompose the task's life exactly:
/// compute `[compute_start, compute_end]`, writer-queue wait
/// `[compute_end, ready]` (pipelined configs only), token stall
/// `[ready, reduce_start]` (of which the final `l2_wait` is L2 signal
/// propagation), reduce `[reduce_start, reduce_end]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskSpan {
    /// SM execution slot that ran the task (physical SM x occupancy).
    pub sm: usize,
    /// Chain index in the schedule.
    pub chain: usize,
    /// Head instance.
    pub head: usize,
    /// KV tile (owning axis).
    pub kv: usize,
    /// Q tile visited.
    pub q: usize,
    /// Compute start time.
    pub compute_start: f64,
    /// Compute end time.
    pub compute_end: f64,
    /// When the fold became eligible: compute done *and* the SM's writer
    /// warp free. `reduce_start - ready` is this task's token stall.
    pub ready: f64,
    /// Reduce start time (= `ready` + any token stall).
    pub reduce_start: f64,
    /// Reduce end time.
    pub reduce_end: f64,
    /// Portion of the token stall spent on L2 signal propagation from the
    /// previous contributor's SM (the tail of `[ready, reduce_start]`).
    pub l2_wait: f64,
}

/// One simulated interconnect transfer: a pipeline stage of the
/// cross-device ring reduce. Cluster simulations model the interconnect as
/// first-class lanes — `D` links, each carrying `D-1` sequential hop
/// stages after the last device finishes computing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpan {
    /// Interconnect lane index (`0..n_devices`; link `i` connects device
    /// `i` to device `(i+1) % n_devices`).
    pub link: usize,
    /// Ring-reduce pipeline stage (`0..n_devices-1`).
    pub step: usize,
    /// Sending device.
    pub src: usize,
    /// Receiving device.
    pub dst: usize,
    /// Transfer start time.
    pub t_start: f64,
    /// Transfer end time (`t_start + hop_cost`).
    pub t_end: f64,
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total makespan (cycles).
    pub makespan: f64,
    /// Sum over SMs of compute-busy time (the dQ-writer warp runs in
    /// parallel; its time is in `reduce_busy`).
    pub busy_time: f64,
    /// Sum over writer warps of reduce-busy time.
    pub reduce_busy: f64,
    /// Sum over tasks of *token-wait* time: how long folds sat blocked on
    /// the serialized accumulation order (the determinism cost). Pipeline
    /// slot waits and the reduces themselves are not counted.
    pub stall_time: f64,
    /// Number of simulated tasks.
    pub n_tasks: usize,
    /// Number of SMs that executed at least one task (summed over devices
    /// for cluster schedules).
    pub n_sm_used: usize,
    /// Per-task spans (empty unless `record_spans`). For cluster
    /// schedules, device `d`'s spans occupy execution slots
    /// `[d * n_sm * occupancy, (d+1) * n_sm * occupancy)`.
    pub spans: Vec<TaskSpan>,
    /// Interconnect transfer spans (empty for single-device runs; always
    /// recorded for cluster runs — there are only `D * (D-1)` of them).
    pub links: Vec<LinkSpan>,
}

impl SimResult {
    /// Machine utilization in [0, 1]: busy / (makespan * n_sm_used).
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 || self.n_sm_used == 0 {
            return 0.0;
        }
        self.busy_time / (self.makespan * self.n_sm_used as f64)
    }
}

/// Simulation failure modes.
#[derive(Debug, Clone, PartialEq)]
pub enum SimError {
    /// The reduction order references a contribution that no chain produces,
    /// or chains deadlocked on each other (illegal schedule).
    Deadlock {
        /// Human-readable diagnosis of what deadlocked.
        detail: String,
    },
    /// A [`CostModel`] field is NaN or infinite — rejected up front by
    /// [`CostModel::validate`] instead of panicking mid-simulation.
    NonFiniteCost {
        /// Which cost-model field failed validation.
        field: &'static str,
        /// The offending value.
        value: f64,
    },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Deadlock { detail } => write!(f, "deadlock: {detail}"),
            Self::NonFiniteCost { field, value } => {
                write!(f, "non-finite cost model field {field} = {value}")
            }
        }
    }
}
impl std::error::Error for SimError {}

/// Per-(head, q) serialized-accumulation semaphore state.
#[derive(Debug, Clone, Copy, Default)]
struct Token {
    /// Position in the reduction order of the next allowed contributor.
    next: usize,
    /// Time the previous contribution finished folding.
    release_time: f64,
    /// SM that folded the previous contribution (for L2 latency).
    release_sm: usize,
}

/// A computed tile waiting in the SM's writer FIFO.
struct Pending {
    chain: usize,
    task_idx: usize,
    compute_end: f64,
    /// Stream index of this task on its SM (for slot accounting).
    stream_idx: usize,
}

/// Per-execution-slot state (physical SM x occupancy).
#[derive(Default)]
struct SmState {
    fifo: VecDeque<Pending>,
    /// When the writer warp finishes its current fold.
    writer_free: f64,
    /// reduce_end per stream index (folds complete in FIFO order).
    fold_end: Vec<f64>,
    /// Tasks dispatched to compute so far (next stream index).
    stream: usize,
    /// Deferred next compute: (chain, task_idx, earliest_start,
    /// fold index whose completion frees its pipeline slot).
    pending_compute: Option<(usize, usize, f64, usize)>,
    used: bool,
    busy_compute: f64,
}

impl SmState {
    /// Back to the t = 0 state, keeping the FIFO/fold allocations.
    fn reset(&mut self) {
        self.fifo.clear();
        self.writer_free = 0.0;
        self.fold_end.clear();
        self.stream = 0;
        self.pending_compute = None;
        self.used = false;
        self.busy_compute = 0.0;
    }
}

/// Total-ordered f64 for the event heap. `total_cmp` (IEEE 754
/// totalOrder) cannot panic, unlike the `partial_cmp().unwrap()` this
/// replaced — and [`CostModel::validate`] keeps NaN out of the timestamps
/// in the first place.
#[derive(PartialEq)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

const NO_POS: u32 = u32::MAX;
const NO_WAITER: u32 = u32::MAX;
const NO_SLOT: u32 = u32::MAX;

/// Every working buffer of one simulation, owned together so a
/// [`Simulator`] can clear them between runs instead of reallocating.
#[derive(Default)]
struct SimBuffers {
    /// Dense (head, q, kv) -> reduction-order position (NO_POS = absent).
    /// Flat tables beat hash maps ~3x on the full Fig-8/9 sweep (§Perf).
    position: Vec<u32>,
    /// Semaphore per (head, q).
    tokens: Vec<Token>,
    /// Parked SM per (head, q, order position) (NO_WAITER = none).
    waiters: Vec<u32>,
    /// Pinned-chain queue per execution slot.
    sm_queue: Vec<VecDeque<usize>>,
    /// Launch-ordered dynamic chain queue.
    grid_queue: VecDeque<usize>,
    /// Dense (physical SM, head id) -> execution slot (NO_SLOT = unset);
    /// replaces the `HashMap<(usize, usize), usize>` the setup path used
    /// to allocate per call.
    head_slot: Vec<u32>,
    /// Per-slot execution state.
    sms: Vec<SmState>,
    /// Compute-start events: (time, seq, sm, chain, task_idx).
    heap: BinaryHeap<Reverse<(OrdF64, usize, usize, usize, usize)>>,
    /// Cross-SM token-release cascade worklist (drained every event).
    work: Vec<usize>,
    /// Span storage (handed to the caller on record_spans runs).
    spans: Vec<TaskSpan>,
}

/// A reusable simulation context: owns all working buffers and clears
/// (never frees) them between runs. Use one `Simulator` per thread for
/// repeated-simulation workloads — autotune search, sweep grids, the
/// verify matrix — and [`simulate`] for one-shot calls.
///
/// Buffer-reuse contract: `run` resets every buffer *at its start*, so a
/// run's result is independent of whatever ran before it (including runs
/// that returned an error mid-flight) and bitwise-identical to a fresh
/// [`simulate`] call — pinned by `tests/perf_equivalence.rs`.
#[derive(Default)]
pub struct Simulator {
    buf: SimBuffers,
}

impl Simulator {
    /// A fresh context with empty buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Run the engine on `schedule`. See the module docs for semantics.
    /// Cluster schedules (`schedule.cluster` with more than one device)
    /// simulate each device's chain subset independently and append the
    /// cross-device ring-reduce epilogue; single-device schedules take the
    /// plain path bit-for-bit unchanged.
    pub fn run(&mut self, schedule: &Schedule, config: &SimConfig) -> Result<SimResult, SimError> {
        if schedule.cluster.as_ref().is_some_and(|c| c.n_devices > 1) {
            return self.run_cluster(schedule, config);
        }
        self.run_single(schedule, config)
    }

    /// Multi-device path: each device runs its chain subset (pinned slots
    /// compacted, reduction orders filtered to the device's KV rows — a
    /// pure constraint *removal*, so a schedule that completes unsharded
    /// completes sharded), devices execute concurrently, and the makespan
    /// ends with the `D-1` pipelined hop stages of the cross-device
    /// dK/dV + dQ ring reduce over the fixed `xdev_order`.
    fn run_cluster(
        &mut self,
        schedule: &Schedule,
        config: &SimConfig,
    ) -> Result<SimResult, SimError> {
        let cluster = schedule.cluster.as_ref().expect("cluster schedule");
        let n_devices = cluster.n_devices;
        let hop = cluster.hop_cost;
        if !hop.is_finite() {
            return Err(SimError::NonFiniteCost { field: "cluster.hop_cost", value: hop });
        }
        let lanes_per_dev = config.n_sm * config.occupancy.max(1);
        let mut agg = SimResult {
            makespan: 0.0,
            busy_time: 0.0,
            reduce_busy: 0.0,
            stall_time: 0.0,
            n_tasks: 0,
            n_sm_used: 0,
            spans: Vec::new(),
            links: Vec::new(),
        };
        // Time the slowest device finishes its local compute + folds; the
        // ring reduce starts here (every stage needs every device's slab).
        let mut compute_done = 0.0f64;
        for d in 0..n_devices {
            let (sub, chain_map) = device_subschedule(schedule, d);
            let r = self.run_single(&sub, config)?;
            compute_done = compute_done.max(r.makespan);
            agg.busy_time += r.busy_time;
            agg.reduce_busy += r.reduce_busy;
            agg.stall_time += r.stall_time;
            agg.n_tasks += r.n_tasks;
            agg.n_sm_used += r.n_sm_used;
            agg.spans.extend(r.spans.into_iter().map(|mut s| {
                s.sm += d * lanes_per_dev;
                s.chain = chain_map[s.chain];
                s
            }));
        }
        // Ring-reduce epilogue: D-1 pipeline stages, all D links busy each
        // stage (device i sends its accumulated slab to i+1).
        for step in 0..n_devices - 1 {
            for link in 0..n_devices {
                agg.links.push(LinkSpan {
                    link,
                    step,
                    src: link,
                    dst: (link + 1) % n_devices,
                    t_start: compute_done + step as f64 * hop,
                    t_end: compute_done + (step + 1) as f64 * hop,
                });
            }
        }
        agg.makespan = compute_done + (n_devices - 1) as f64 * hop;
        if config.record_spans {
            agg.spans.sort_by(|a, b| a.compute_start.total_cmp(&b.compute_start));
        }
        Ok(agg)
    }

    /// Single-device event loop (the pre-cluster `run`, byte-identical
    /// semantics).
    fn run_single(
        &mut self,
        schedule: &Schedule,
        config: &SimConfig,
    ) -> Result<SimResult, SimError> {
        config.cost.validate()?;
        let spec = &schedule.spec;
        let occ = config.occupancy.max(1);
        // `occ` co-resident CTAs per SM = `occ` execution slots, each at
        // 1/occ of the SM's compute rate. Slot `s` lives on physical SM
        // `s / occ` (L2 locality uses physical SMs).
        let n_sm = config.n_sm * occ;
        assert!(n_sm > 0, "need at least one SM");
        let cost = &config.cost;
        let depth = config.writer_depth;
        let compute_scale_occ = occ as f64;

        let n_q = spec.n_q.max(1);
        let n_kv = spec.n_kv.max(1);
        let n_tok = schedule.reduction_order.len();

        // --- reset buffers (clear, don't free) ----------------------------
        let SimBuffers {
            position,
            tokens,
            waiters,
            sm_queue,
            grid_queue,
            head_slot,
            sms,
            heap,
            work,
            spans,
        } = &mut self.buf;
        position.clear();
        position.resize(n_tok * n_kv, NO_POS);
        for (idx, order) in schedule.reduction_order.iter().enumerate() {
            for (p, &kv) in order.iter().enumerate() {
                position[idx * n_kv + kv] = p as u32;
            }
        }
        let key = |head: usize, q: usize| head * n_q + q;
        tokens.clear();
        tokens.resize(n_tok, Token::default());
        waiters.clear();
        waiters.resize(n_tok * n_kv, NO_WAITER);
        if sm_queue.len() < n_sm {
            sm_queue.resize_with(n_sm, Default::default);
        }
        for q in sm_queue[..n_sm].iter_mut() {
            q.clear();
        }
        grid_queue.clear();
        if sms.len() < n_sm {
            sms.resize_with(n_sm, Default::default);
        }
        for s in sms[..n_sm].iter_mut() {
            s.reset();
        }
        heap.clear();
        work.clear();
        spans.clear();

        // --- chain queues -------------------------------------------------
        // Head ids can exceed `spec.n_heads` (two-pass uses virtual heads
        // for its second pass), so the slot table is sized by the largest
        // head id actually present.
        let n_head_ids = schedule.chains.iter().map(|c| c.head + 1).max().unwrap_or(1);
        head_slot.clear();
        head_slot.resize(config.n_sm * n_head_ids, NO_SLOT);
        for i in 0..schedule.chains.len() {
            match schedule.placement(i, config.n_sm) {
                Some(sm) => {
                    // Pinned chains fill the SM's co-resident CTA slots in
                    // queue-balance order; all chains of one head on one SM
                    // share a slot (symmetric shift's paired chains must run
                    // back to back on the same CTA stream).
                    let head = schedule.chains[i].head;
                    let cell = sm * n_head_ids + head;
                    if head_slot[cell] == NO_SLOT {
                        head_slot[cell] = (sm * occ..sm * occ + occ)
                            .min_by_key(|&sl| sm_queue[sl].len())
                            .unwrap() as u32;
                    }
                    sm_queue[head_slot[cell] as usize].push_back(i);
                }
                None => grid_queue.push_back(i),
            }
        }

        let mut seq = 0usize;
        let mut makespan = 0.0f64;
        let mut stall_time = 0.0f64;
        let mut n_tasks = 0usize;
        let mut total_reduce_busy = 0.0f64;
        let mut completed_chains = 0usize;
        let total_chains = schedule.chains.len();

        // Pull the next chain for an SM (skipping empty chains); returns
        // (chain, first task index) or None.
        let mut pull = |sm: usize,
                        sm_queue: &mut Vec<VecDeque<usize>>,
                        grid_queue: &mut VecDeque<usize>,
                        completed: &mut usize|
         -> Option<usize> {
            loop {
                let next = match (sm_queue[sm].front(), grid_queue.front()) {
                    (Some(&p), Some(&g)) => {
                        if p < g {
                            sm_queue[sm].pop_front()
                        } else {
                            grid_queue.pop_front()
                        }
                    }
                    (Some(_), None) => sm_queue[sm].pop_front(),
                    (None, Some(_)) => grid_queue.pop_front(),
                    (None, None) => return None,
                }?;
                if schedule.chains[next].is_empty() {
                    *completed += 1;
                    continue;
                }
                return Some(next);
            }
        };

        // Kick off every SM at t = 0.
        for sm in 0..n_sm {
            if let Some(ci) = pull(sm, &mut *sm_queue, &mut *grid_queue, &mut completed_chains) {
                heap.push(Reverse((OrdF64(0.0), seq, sm, ci, 0)));
                seq += 1;
            }
        }

        // Drain as many FIFO-head folds as possible on `sm`; returns SMs
        // whose tokens were released (to be advanced in turn by the caller).
        macro_rules! advance_writer {
            ($sm:expr, $work:expr) => {{
                let sm = $sm;
                loop {
                    let Some(front) = sms[sm].fifo.front() else { break };
                    let fch = &schedule.chains[front.chain];
                    let fq = fch.q_order[front.task_idx];
                    let fordered = fch.ordered && !schedule.reduction_order.is_empty();
                    let mut token_release = f64::NEG_INFINITY;
                    let mut token_l2 = 0.0f64;
                    if fordered {
                        let tok_idx = key(fch.head, fq);
                        let pos = position[tok_idx * n_kv + fch.kv];
                        if pos == NO_POS {
                            return Err(SimError::Deadlock {
                                detail: format!(
                                    "no reduction-order slot for head {} q {} kv {}",
                                    fch.head, fq, fch.kv
                                ),
                            });
                        }
                        let tok = &tokens[tok_idx];
                        if tok.next != pos as usize {
                            // Not our turn: park this SM's writer on the token.
                            waiters[tok_idx * n_kv + pos as usize] = sm as u32;
                            break;
                        }
                        if tok.next > 0 {
                            token_l2 = cost
                                .l2
                                .signal_latency(tok.release_sm / occ, sm / occ, config.n_sm);
                            token_release = tok.release_time + token_l2;
                        }
                    }
                    let front = sms[sm].fifo.pop_front().unwrap();
                    let fch = &schedule.chains[front.chain];
                    let fq = fch.q_order[front.task_idx];
                    let r = cost.reduce * fch.reduce_scale;
                    let ready = front.compute_end.max(sms[sm].writer_free);
                    let reduce_start = ready.max(token_release);
                    let reduce_end = reduce_start + r;
                    sms[sm].writer_free = reduce_end;
                    debug_assert_eq!(sms[sm].fold_end.len(), front.stream_idx);
                    sms[sm].fold_end.push(reduce_end);
                    stall_time += reduce_start - ready; // token wait only
                    total_reduce_busy += r;
                    makespan = makespan.max(reduce_end);
                    n_tasks += 1;
                    if config.record_spans {
                        let fc = cost.compute
                            * fch.compute_scale
                            * cost.spill_factor
                            * compute_scale_occ;
                        // Of the token stall [ready, reduce_start], the signal
                        // latency forms the tail; the rest is serialization
                        // wait for the previous contributor's fold to finish.
                        let l2_wait = (reduce_start - ready).min(token_l2).max(0.0);
                        spans.push(TaskSpan {
                            sm,
                            chain: front.chain,
                            head: fch.head,
                            kv: fch.kv,
                            q: fq,
                            compute_start: front.compute_end - fc,
                            compute_end: front.compute_end,
                            ready,
                            reduce_start,
                            reduce_end,
                            l2_wait,
                        });
                    }
                    // Advance the token; wake the next contributor's SM.
                    if fch.ordered && !schedule.reduction_order.is_empty() {
                        let tok_idx = key(fch.head, fq);
                        let order_len = schedule.reduction_order[tok_idx].len();
                        let tok = &mut tokens[tok_idx];
                        tok.next += 1;
                        tok.release_time = reduce_end;
                        tok.release_sm = sm;
                        if tok.next < order_len {
                            let w = &mut waiters[tok_idx * n_kv + tok.next];
                            if *w != NO_WAITER {
                                $work.push(*w as usize);
                                *w = NO_WAITER;
                            }
                        }
                    }
                    // Free a pipeline slot: maybe resume this SM's compute.
                    if let Some((chain, task_idx, earliest, need)) = sms[sm].pending_compute {
                        if sms[sm].fold_end.len() > need {
                            let start = earliest.max(sms[sm].fold_end[need]);
                            sms[sm].pending_compute = None;
                            heap.push(Reverse((OrdF64(start), seq, sm, chain, task_idx)));
                            seq += 1;
                        }
                    }
                }
            }};
        }

        while let Some(Reverse((OrdF64(time), _, sm, chain, task_idx))) = heap.pop() {
            let ch = &schedule.chains[chain];
            sms[sm].used = true;

            // Compute phase (slot rate = SM rate / occupancy).
            let c = cost.compute * ch.compute_scale * cost.spill_factor * compute_scale_occ;
            let compute_end = time + c;
            sms[sm].busy_compute += c;
            makespan = makespan.max(compute_end);
            let stream_idx = sms[sm].stream;
            sms[sm].stream += 1;
            sms[sm].fifo.push_back(Pending { chain, task_idx, compute_end, stream_idx });

            // Drain writers; cross-SM token releases cascade via the
            // (reused) worklist, which is always drained back to empty.
            advance_writer!(sm, work);
            while let Some(wsm) = work.pop() {
                advance_writer!(wsm, work);
            }

            // Determine the next compute work unit for this SM.
            let next_unit = if task_idx + 1 < schedule.chains[chain].len() {
                Some((chain, task_idx + 1))
            } else {
                completed_chains += 1;
                pull(sm, &mut *sm_queue, &mut *grid_queue, &mut completed_chains)
                    .map(|ci| (ci, 0))
            };
            if let Some((nc, nt)) = next_unit {
                // Pipeline constraint within a chain: at most `depth` unreduced
                // tiles in flight (depth 0 = synchronous §3 model). Across
                // chains: the CTA only exits — freeing the SM for the next
                // chain — once its writer has drained (all folds done), so a
                // new chain waits for the previous chain's last fold.
                let new_chain = nc != chain;
                let need_idx: Option<usize> = if depth == 0 || new_chain {
                    Some(stream_idx)
                } else if stream_idx + 1 >= depth {
                    Some(stream_idx + 1 - depth)
                } else {
                    None
                };
                match need_idx {
                    None => {
                        heap.push(Reverse((OrdF64(compute_end), seq, sm, nc, nt)));
                        seq += 1;
                    }
                    Some(fi) if sms[sm].fold_end.len() > fi => {
                        let start = compute_end.max(sms[sm].fold_end[fi]);
                        heap.push(Reverse((OrdF64(start), seq, sm, nc, nt)));
                        seq += 1;
                    }
                    Some(fi) => {
                        sms[sm].pending_compute = Some((nc, nt, compute_end, fi));
                    }
                }
            }
        }

        // Every chain must have completed and every FIFO drained.
        let undrained: usize = sms[..n_sm].iter().map(|s| s.fifo.len()).sum();
        if completed_chains != total_chains || undrained > 0 {
            return Err(SimError::Deadlock {
                detail: format!(
                    "{} of {} chains completed, {} folds undrained; schedule {} deadlocked",
                    completed_chains,
                    total_chains,
                    undrained,
                    schedule.kind.name()
                ),
            });
        }

        if config.record_spans {
            spans.sort_by(|a, b| a.compute_start.total_cmp(&b.compute_start));
        }
        Ok(SimResult {
            makespan,
            busy_time: sms[..n_sm].iter().map(|s| s.busy_compute).sum::<f64>(),
            reduce_busy: total_reduce_busy,
            stall_time,
            n_tasks,
            n_sm_used: sms[..n_sm].iter().filter(|s| s.used).count(),
            // Hand the span buffer to the caller (record_spans runs only —
            // the hot sweep path keeps its empty Vec, no allocation).
            spans: std::mem::take(spans),
            links: Vec::new(),
        })
    }
}

/// Extract device `d`'s sub-schedule from a cluster schedule: its chains
/// in launch order, pinned slots compacted to a dense per-device wave,
/// and every (head, q) reduction order filtered to the device's own KV
/// rows. Returns the sub-schedule plus the map from sub-chain index back
/// to the parent schedule's chain index (for span attribution).
///
/// Filtering only *removes* wait dependencies: a trace of the full
/// schedule with the other devices' tasks deleted is a feasible execution
/// of the sub-schedule, so sharding can never introduce a deadlock.
fn device_subschedule(schedule: &Schedule, d: usize) -> (Schedule, Vec<usize>) {
    let cluster = schedule.cluster.as_ref().expect("cluster schedule");
    let spec = &schedule.spec;
    let ww = schedule.wave_width.max(1);
    let mut chains = Vec::new();
    let mut pinned = Vec::new();
    let mut chain_map = Vec::new();
    let mut owned_kv = vec![false; spec.n_kv.max(1)];
    let mut slots: Vec<usize> = Vec::new();
    for (i, ch) in schedule.chains.iter().enumerate() {
        if cluster.device[i] != d {
            continue;
        }
        chain_map.push(i);
        chains.push(ch.clone());
        pinned.push(schedule.pinned[i]);
        if ch.kv < owned_kv.len() {
            owned_kv[ch.kv] = true;
        }
        if let Some(slot) = schedule.pinned[i] {
            slots.push(slot % ww);
        }
    }
    // Compact the device's pinned slots to ranks 0..k so its wave packs
    // onto contiguous SMs (wave_width = the device's distinct slot count).
    slots.sort_unstable();
    slots.dedup();
    for p in pinned.iter_mut() {
        if let Some(slot) = p.as_mut() {
            *slot = slots.binary_search(&(*slot % ww)).expect("slot was collected");
        }
    }
    let reduction_order = schedule
        .reduction_order
        .iter()
        .map(|order| {
            order
                .iter()
                .copied()
                .filter(|&kv| kv < owned_kv.len() && owned_kv[kv])
                .collect()
        })
        .collect();
    (
        Schedule {
            spec: spec.clone(),
            kind: schedule.kind,
            chains,
            pinned,
            wave_width: slots.len().max(1),
            reduction_order,
            cluster: None,
        },
        chain_map,
    )
}

/// Run the engine once with fresh buffers. See module docs for semantics;
/// repeated-simulation loops should hold a [`Simulator`] instead.
pub fn simulate(schedule: &Schedule, config: &SimConfig) -> Result<SimResult, SimError> {
    Simulator::new().run(schedule, config)
}

/// Simulate every schedule in `schedules` under `config`, fanned across
/// up to `threads` host threads (`0` = all cores, `1` = serial in the
/// calling thread). Each worker reuses one [`Simulator`], and results come
/// back in input order — the output is bitwise-identical to a serial
/// `schedules.iter().map(|s| simulate(s, config))` at any thread count.
pub fn simulate_batch(
    schedules: &[Schedule],
    config: &SimConfig,
    threads: usize,
) -> Vec<Result<SimResult, SimError>> {
    crate::util::parallel::par_map_init(schedules, threads, Simulator::new, |sim, s| {
        sim.run(s, config)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{
        descending, fa3, fa3::fa3_atomic, shift, symmetric_shift, two_pass, MaskSpec,
        ProblemSpec,
    };

    fn ideal(n: usize) -> SimConfig {
        SimConfig::ideal(n)
    }

    #[test]
    fn shift_full_matches_optimum() {
        let (n, m) = (8, 3);
        let s = shift(&ProblemSpec::square(n, m, MaskSpec::full())).unwrap();
        let r = simulate(&s, &ideal(n)).unwrap();
        assert!((r.makespan - (m * n) as f64 * 1.25).abs() < 1e-9, "{}", r.makespan);
        assert!(r.stall_time < 1e-9, "optimal schedule must have no stalls");
    }

    #[test]
    fn fa3_full_matches_closed_form() {
        let (n, m) = (6, 2);
        let s = fa3(&ProblemSpec::square(n, m, MaskSpec::full()), true);
        let r = simulate(&s, &ideal(n)).unwrap();
        // The formula's startup term is approximate ("up to negligible
        // control overhead", §3.2): dynamic chain hand-off lets the second
        // head's chains overlap part of the first head's staggered
        // completions, so the engine lands within one startup term below.
        let expect = (m * n) as f64 * 1.25 + (n as f64 - 1.0) * 0.25;
        let optimum = (m * n) as f64 * 1.25;
        assert!(r.makespan <= expect + 1e-9, "{} vs {expect}", r.makespan);
        assert!(r.makespan >= optimum - 1e-9, "{} vs optimum {optimum}", r.makespan);
    }

    #[test]
    fn symmetric_shift_causal_matches_optimum() {
        let (n, m) = (8, 2);
        let s = symmetric_shift(&ProblemSpec::square(n, m, MaskSpec::causal()));
        let r = simulate(&s, &ideal(n)).unwrap();
        let expect = (m * (n + 1)) as f64 * 1.25 / 2.0;
        assert!((r.makespan - expect).abs() < 1e-9, "{} vs {expect}", r.makespan);
        assert!(r.stall_time < 1e-9);
    }

    #[test]
    fn atomic_is_not_slower_than_deterministic() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let det = simulate(&fa3(&spec, true), &ideal(8)).unwrap();
        let atomic = simulate(&fa3_atomic(&spec), &ideal(8)).unwrap();
        assert!(atomic.makespan <= det.makespan + 1e-9);
        assert!(atomic.stall_time < 1e-9);
    }

    #[test]
    fn descending_beats_fa3_on_causal_multihead() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let base = simulate(&fa3(&spec, true), &ideal(8)).unwrap();
        let desc = simulate(&descending(&spec), &ideal(8)).unwrap();
        assert!(
            desc.makespan < base.makespan,
            "descending {} vs fa3 {}",
            desc.makespan,
            base.makespan
        );
    }

    #[test]
    fn descending_approaches_paper_formula() {
        // T_reversed ≈ m(n+1)(c+r)/2 + (n-1) r for even m.
        let (n, m) = (8, 6);
        let s = descending(&ProblemSpec::square(n, m, MaskSpec::causal()));
        let r = simulate(&s, &ideal(n)).unwrap();
        let expect = (m * (n + 1)) as f64 * 1.25 / 2.0 + (n as f64 - 1.0) * 0.25;
        // Heuristic, not exact: allow 15% slack above, must not be faster
        // than the optimum either.
        let optimum = (m * (n + 1)) as f64 * 1.25 / 2.0;
        assert!(r.makespan >= optimum - 1e-9);
        assert!(r.makespan <= expect * 1.15, "{} vs {expect}", r.makespan);
    }

    #[test]
    fn two_pass_completes_and_is_slower_than_fused_descending() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let tp = simulate(&two_pass(&spec), &ideal(8)).unwrap();
        let desc = simulate(&descending(&spec), &ideal(8)).unwrap();
        assert!(tp.makespan > desc.makespan);
    }

    #[test]
    fn l2_latency_hurts_shift_only_beyond_compute_slack() {
        // Each shift handoff has `c` of slack (the consumer computes while
        // the signal travels). λ < c is absorbed; λ > c compounds — the
        // §4.2 sensitivity that erodes shift's edge at extreme parallelism.
        let n = 64;
        let spec = ProblemSpec::square(n, 2, MaskSpec::full());
        let mk = |l2: L2Model, compute: f64| SimConfig {
            n_sm: n,
            cost: CostModel { compute, reduce: 0.3 * compute, spill_factor: 1.0, l2 },
            record_spans: false,
            writer_depth: 0,
            occupancy: 1,
            hw_fingerprint: 0,
        };
        let big_c = simulate(&shift(&spec).unwrap(), &mk(L2Model::default(), 1000.0)).unwrap();
        let big_c_ideal =
            simulate(&shift(&spec).unwrap(), &mk(L2Model::ideal(), 1000.0)).unwrap();
        assert!(
            (big_c.makespan - big_c_ideal.makespan).abs() < 1e-6,
            "λ < c must be absorbed by compute slack"
        );
        let small_c = simulate(&shift(&spec).unwrap(), &mk(L2Model::default(), 100.0)).unwrap();
        let small_c_ideal =
            simulate(&shift(&spec).unwrap(), &mk(L2Model::ideal(), 100.0)).unwrap();
        assert!(
            small_c.makespan > small_c_ideal.makespan * 1.2,
            "λ > c must compound: {} vs {}",
            small_c.makespan,
            small_c_ideal.makespan
        );
    }

    #[test]
    fn spans_recorded_and_sorted() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::causal());
        let mut cfg = ideal(4);
        cfg.record_spans = true;
        let r = simulate(&fa3(&spec, true), &cfg).unwrap();
        assert_eq!(r.spans.len(), r.n_tasks);
        assert!(r.spans.windows(2).all(|w| w[0].compute_start <= w[1].compute_start));
    }

    #[test]
    fn utilization_bounded() {
        let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
        let r = simulate(&fa3(&spec, true), &ideal(8)).unwrap();
        let u = r.utilization();
        assert!(u > 0.0 && u <= 1.0 + 1e-9, "utilization {u}");
    }

    #[test]
    fn more_sms_than_chains_leaves_sms_idle_but_completes() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::full());
        let r = simulate(&fa3(&spec, true), &ideal(16)).unwrap();
        assert_eq!(r.n_sm_used, 4);
        assert_eq!(r.n_tasks, 16);
    }

    #[test]
    fn corrupt_reduction_order_deadlocks_cleanly() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::full());
        let mut s = fa3(&spec, true);
        // Make q=0's order expect a contribution kv=0 twice (kv=1 missing):
        s.reduction_order[0] = vec![1, 0, 2, 3];
        // swap order so kv 1 must go first but kv1's chain computes q0 first
        // anyway — this is still satisfiable; instead drop a contributor:
        s.reduction_order[0] = vec![0, 2, 3]; // kv=1 has no slot -> error
        let err = simulate(&s, &SimConfig::ideal(4)).unwrap_err();
        assert!(matches!(err, SimError::Deadlock { .. }));
    }

    #[test]
    fn buffered_reuse_is_bitwise_identical_to_fresh_runs() {
        // One Simulator driven across different problems, machine widths,
        // occupancies, and even an error in the middle must reproduce the
        // single-shot path exactly (buffers reset at the start of `run`).
        let mut sim = Simulator::new();
        let mut cfg_big = ideal(16);
        cfg_big.record_spans = true;
        let mut cfg_small = SimConfig::fa3_pipeline(3, CostModel::default(), 2);
        cfg_small.record_spans = true;
        let runs: Vec<(Schedule, SimConfig)> = vec![
            (fa3(&ProblemSpec::square(8, 3, MaskSpec::causal()), true), cfg_big),
            (symmetric_shift(&ProblemSpec::square(8, 2, MaskSpec::causal())), cfg_big),
            (descending(&ProblemSpec::square(5, 2, MaskSpec::full())), cfg_small),
            (two_pass(&ProblemSpec::square(6, 2, MaskSpec::causal())), cfg_big),
        ];
        for (i, (s, cfg)) in runs.iter().enumerate() {
            if i == 2 {
                // Inject a failing run; the next run must be unaffected.
                let mut bad = fa3(&ProblemSpec::square(4, 1, MaskSpec::full()), true);
                bad.reduction_order[0] = vec![0, 2, 3];
                assert!(sim.run(&bad, &ideal(4)).is_err());
            }
            let buffered = sim.run(s, cfg).unwrap();
            let fresh = simulate(s, cfg).unwrap();
            assert_eq!(buffered.makespan.to_bits(), fresh.makespan.to_bits());
            assert_eq!(buffered.stall_time.to_bits(), fresh.stall_time.to_bits());
            assert_eq!(buffered.busy_time.to_bits(), fresh.busy_time.to_bits());
            assert_eq!(buffered.n_tasks, fresh.n_tasks);
            assert_eq!(buffered.n_sm_used, fresh.n_sm_used);
            assert_eq!(buffered.spans, fresh.spans);
        }
    }

    #[test]
    fn non_finite_costs_are_rejected_up_front() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::full());
        let s = fa3(&spec, true);
        for (patch, field) in [
            (0usize, "compute"),
            (1, "reduce"),
            (2, "spill_factor"),
        ] {
            for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
                let mut cfg = ideal(4);
                match patch {
                    0 => cfg.cost.compute = bad,
                    1 => cfg.cost.reduce = bad,
                    _ => cfg.cost.spill_factor = bad,
                }
                let err = simulate(&s, &cfg).unwrap_err();
                assert!(
                    matches!(err, SimError::NonFiniteCost { field: f, .. } if f == field),
                    "{field} = {bad} must be rejected, got {err}"
                );
            }
        }
        let mut cfg = ideal(4);
        cfg.cost.l2.remote_latency = f64::NAN;
        assert!(matches!(simulate(&s, &cfg), Err(SimError::NonFiniteCost { .. })));
    }

    #[test]
    fn degenerate_cluster_annotation_is_bitwise_identical_to_plain() {
        use crate::schedule::{ring, ScheduleKind};
        // D = 1 cluster schedules take the plain single-device path.
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let plain = shift(&spec).unwrap();
        let annotated = ring(&spec, ScheduleKind::Shift, 1).unwrap();
        let mut cfg = ideal(8);
        cfg.record_spans = true;
        let a = simulate(&plain, &cfg).unwrap();
        let b = simulate(&annotated, &cfg).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.stall_time.to_bits(), b.stall_time.to_bits());
        assert_eq!(a.spans, b.spans);
        assert!(b.links.is_empty());
    }

    #[test]
    fn ring_shift_two_devices_matches_closed_form() {
        use crate::schedule::{ring, ScheduleKind};
        // Full mask, n = 8, 2 heads, ideal(8), D = 2: each device's wave
        // is 4 SMs wide, so its two heads run concurrently on SM halves —
        // per-device makespan 8 * 1.25 = 10, plus one abstract hop = 11.
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 2).unwrap();
        let r = simulate(&s, &ideal(8)).unwrap();
        assert!((r.makespan - 11.0).abs() < 1e-9, "{}", r.makespan);
        assert!(r.stall_time < 1e-9, "sharded shift must stay stall-free");
        assert_eq!(r.n_tasks, 128);
        assert_eq!(r.n_sm_used, 16);
        assert!((r.busy_time - 128.0).abs() < 1e-9);
        // D * (D-1) = 2 link spans, covering [10, 11] on both links.
        assert_eq!(r.links.len(), 2);
        for l in &r.links {
            assert!((l.t_start - 10.0).abs() < 1e-9 && (l.t_end - 11.0).abs() < 1e-9);
            assert_eq!(l.dst, (l.src + 1) % 2);
        }
    }

    #[test]
    fn ring_shift_four_devices_matches_closed_form() {
        use crate::schedule::{ring, ScheduleKind};
        // D = 4: per-device wave = 2 SMs, 4 head waves on 8 SMs host both
        // heads concurrently; per-device makespan 10, plus 3 hops = 13.
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 4).unwrap();
        let r = simulate(&s, &ideal(8)).unwrap();
        assert!((r.makespan - 13.0).abs() < 1e-9, "{}", r.makespan);
        assert!(r.stall_time < 1e-9);
        assert_eq!(r.n_sm_used, 16);
        assert_eq!(r.links.len(), 12); // 4 links x 3 pipeline stages
    }

    #[test]
    fn zigzag_devices_get_disjoint_lane_ranges() {
        use crate::schedule::{zigzag, ScheduleKind};
        let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
        let s = zigzag(&spec, ScheduleKind::Descending, 2).unwrap();
        let mut cfg = ideal(6);
        cfg.record_spans = true;
        let r = simulate(&s, &cfg).unwrap();
        assert_eq!(r.n_tasks, s.total_tasks());
        let c = s.cluster.as_ref().unwrap();
        // Span lanes are namespaced per device: device d owns [6d, 6d+6).
        for sp in &r.spans {
            let dev = sp.sm / 6;
            assert!(dev < 2, "lane {} out of range", sp.sm);
            // The span's chain index is the parent schedule's.
            assert_eq!(c.device[sp.chain], dev);
            assert_eq!(s.chains[sp.chain].head, sp.head);
            assert_eq!(s.chains[sp.chain].kv, sp.kv);
        }
        // Hop cost scales the epilogue: doubling it adds D-1 cycles.
        let mut s2 = s.clone();
        s2.cluster.as_mut().unwrap().hop_cost = 2.0;
        let r2 = simulate(&s2, &cfg).unwrap();
        assert!((r2.makespan - r.makespan - 1.0).abs() < 1e-9);
    }

    #[test]
    fn non_finite_hop_cost_is_rejected() {
        use crate::schedule::{ring, ScheduleKind};
        let spec = ProblemSpec::square(8, 1, MaskSpec::full());
        let mut s = ring(&spec, ScheduleKind::Fa3, 2).unwrap();
        s.cluster.as_mut().unwrap().hop_cost = f64::NAN;
        let err = simulate(&s, &ideal(8)).unwrap_err();
        assert!(matches!(err, SimError::NonFiniteCost { field: "cluster.hop_cost", .. }));
    }

    #[test]
    fn simulate_batch_matches_serial_at_any_thread_count() {
        let specs = [
            ProblemSpec::square(6, 2, MaskSpec::causal()),
            ProblemSpec::square(8, 3, MaskSpec::full()),
            ProblemSpec::square(5, 2, MaskSpec::sliding_window(2)),
        ];
        let mut schedules = Vec::new();
        for spec in &specs {
            schedules.push(fa3(spec, true));
            schedules.push(descending(spec));
            schedules.push(symmetric_shift(spec));
        }
        let cfg = ideal(7);
        let serial: Vec<_> = schedules.iter().map(|s| simulate(s, &cfg)).collect();
        for threads in [0usize, 1, 2, 8] {
            let batch = simulate_batch(&schedules, &cfg, threads);
            assert_eq!(batch.len(), serial.len());
            for (b, s) in batch.iter().zip(&serial) {
                match (b, s) {
                    (Ok(b), Ok(s)) => {
                        assert_eq!(b.makespan.to_bits(), s.makespan.to_bits());
                        assert_eq!(b.stall_time.to_bits(), s.stall_time.to_bits());
                        assert_eq!(b.n_tasks, s.n_tasks);
                    }
                    (b, s) => panic!("batch/serial mismatch: {b:?} vs {s:?}"),
                }
            }
        }
    }
}
