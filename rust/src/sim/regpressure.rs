//! Register-pressure / spill model (§4.3).
//!
//! The FA3 backward kernel's per-thread register budget is nearly exhausted
//! at headdim = 128; Symmetric Shift's folded-task-space bookkeeping adds
//! ~10 registers, pushing past the hardware limit and forcing spills to
//! local memory. Spill-induced stalls inflate the effective compute cost —
//! the mechanism behind the Fig 9 inversion where the simpler Descending
//! schedule beats the theoretically-optimal Symmetric Shift at headdim 128.

use crate::schedule::ScheduleKind;

/// Register-budget model for the backward kernel.
#[derive(Debug, Clone, Copy)]
pub struct RegisterModel {
    /// Hardware per-thread register limit (Hopper: 255).
    pub reg_limit: u32,
    /// Base registers used by the FA3 backward consumer warps at
    /// headdim 64 (accumulators dominate).
    pub base_regs_hd64: u32,
    /// Base registers at headdim 128 (double the dK/dV accumulator rows).
    pub base_regs_hd128: u32,
    /// Compute-cost inflation per spilled register (local-memory traffic
    /// replaces register reads on the hot loop).
    pub spill_penalty_per_reg: f64,
    /// Cap on total spill inflation.
    pub max_spill_penalty: f64,
}

impl Default for RegisterModel {
    fn default() -> Self {
        Self {
            reg_limit: 255,
            base_regs_hd64: 184,
            // Nsight-style figure: hd128 sits just under the cliff, so any
            // double-digit overhead spills.
            base_regs_hd128: 248,
            spill_penalty_per_reg: 0.035,
            max_spill_penalty: 1.5,
        }
    }
}

impl RegisterModel {
    /// A model with no spill effects (idealized hardware / Blackwell-TMEM
    /// future work in §4.3).
    pub fn unlimited() -> Self {
        Self { reg_limit: u32::MAX, ..Self::default() }
    }

    /// Base register usage for a head dimension (linear interpolation
    /// between the two calibrated points, clamped).
    pub fn base_regs(&self, head_dim: usize) -> u32 {
        let (r64, r128) = (self.base_regs_hd64 as f64, self.base_regs_hd128 as f64);
        let t = ((head_dim as f64 - 64.0) / 64.0).clamp(0.0, 2.0);
        (r64 + (r128 - r64) * t).round() as u32
    }

    /// Registers spilled for a schedule at a head dimension.
    pub fn spilled_regs(&self, kind: ScheduleKind, head_dim: usize) -> u32 {
        let used = self.base_regs(head_dim) + kind.register_overhead();
        used.saturating_sub(self.reg_limit)
    }

    /// Compute-cost multiplier (>= 1.0) for a schedule at a head dimension.
    pub fn spill_factor(&self, kind: ScheduleKind, head_dim: usize) -> f64 {
        let spilled = self.spilled_regs(kind, head_dim) as f64;
        (1.0 + spilled * self.spill_penalty_per_reg).min(self.max_spill_penalty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hd64_no_schedule_spills() {
        let m = RegisterModel::default();
        for k in [
            ScheduleKind::Fa3,
            ScheduleKind::Descending,
            ScheduleKind::Shift,
            ScheduleKind::SymmetricShift,
        ] {
            assert_eq!(m.spill_factor(k, 64), 1.0, "{k:?} should not spill at hd64");
        }
    }

    #[test]
    fn hd128_symmetric_shift_spills_descending_does_not() {
        // The Fig 9 inversion mechanism.
        let m = RegisterModel::default();
        assert!(m.spill_factor(ScheduleKind::SymmetricShift, 128) > 1.0);
        assert_eq!(m.spill_factor(ScheduleKind::Descending, 128), 1.0);
        assert_eq!(m.spill_factor(ScheduleKind::Fa3, 128), 1.0);
    }

    #[test]
    fn unlimited_never_spills() {
        let m = RegisterModel::unlimited();
        assert_eq!(m.spill_factor(ScheduleKind::SymmetricShift, 128), 1.0);
    }

    #[test]
    fn base_regs_interpolates() {
        let m = RegisterModel::default();
        assert_eq!(m.base_regs(64), 184);
        assert_eq!(m.base_regs(128), 248);
        assert!(m.base_regs(96) > 184 && m.base_regs(96) < 248);
    }

    #[test]
    fn penalty_capped() {
        let m = RegisterModel { base_regs_hd128: 500, ..Default::default() };
        assert_eq!(m.spill_factor(ScheduleKind::SymmetricShift, 128), m.max_spill_penalty);
    }
}
