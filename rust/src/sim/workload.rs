//! Benchmark workload definitions: the paper's §4.1 configurations mapped
//! onto simulator inputs (problem geometry + calibrated cost model).
//!
//! Methodology from the paper: total tokens fixed at 16,384, sequence
//! length swept 512..16,384, hidden dim 2,048, head dims {64, 128},
//! BF16, KV/Q block size 128, NVIDIA H800 (132 SMs, ~1.98 GHz).

use super::engine::{simulate, CostModel, SimConfig, SimResult};
use super::l2::L2Model;
use super::regpressure::RegisterModel;
use crate::attention::flops;
use crate::schedule::{
    descending, fa3, shift, symmetric_shift, two_pass, Mask, ProblemSpec, Schedule,
    ScheduleKind,
};

/// H800 machine constants used across the harness.
pub mod h800 {
    /// Streaming multiprocessors.
    pub const N_SM: usize = 132;
    /// Boost clock, GHz.
    pub const CLOCK_GHZ: f64 = 1.98;
    /// Effective BF16 FLOPs per cycle per SM (dense tensor-core peak
    /// ~3,787/cycle derated to ~65% sustained MXU/WGMMA efficiency —
    /// FA3 reports ~75% of peak on H100 for the fwd pass; bwd is lower).
    pub const FLOPS_PER_CYCLE_PER_SM: f64 = 2460.0;
    /// Effective L2 bandwidth per SM, bytes/cycle, for dQ read-modify-write.
    pub const L2_BYTES_PER_CYCLE_PER_SM: f64 = 32.0;
    /// L2 cache capacity (H800: 50 MiB).
    pub const L2_BYTES: usize = 50 * 1024 * 1024;
}

/// One benchmark configuration (a point on a figure's x-axis).
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Sequence length (512..16,384).
    pub seqlen: usize,
    /// Fixed token budget; batch = total_tokens / seqlen.
    pub total_tokens: usize,
    /// Model hidden dimension (2,048 in the paper).
    pub hidden: usize,
    /// Attention head dimension (64 or 128).
    pub head_dim: usize,
    /// Tile size along both Q and KV (128 in FA3).
    pub block: usize,
    /// Mask shape.
    pub mask: Mask,
}

impl BenchConfig {
    /// The paper's standard sweep point.
    pub fn paper(seqlen: usize, head_dim: usize, mask: Mask) -> Self {
        Self { seqlen, total_tokens: 16384, hidden: 2048, head_dim, block: 128, mask }
    }

    /// KV (= Q) tiles per head.
    pub fn n_tiles(&self) -> usize {
        self.seqlen.div_ceil(self.block)
    }

    /// Independent head instances = batch x heads.
    pub fn head_instances(&self) -> usize {
        let batch = (self.total_tokens / self.seqlen).max(1);
        let heads = self.hidden / self.head_dim;
        batch * heads
    }

    /// Problem geometry for the simulator.
    pub fn spec(&self) -> ProblemSpec {
        ProblemSpec::square(self.n_tiles(), self.head_instances(), self.mask)
    }

    /// Backward-pass FLOPs of the whole workload.
    pub fn total_flops(&self) -> f64 {
        let live = self.mask.total_tiles(self.n_tiles(), self.n_tiles()) as f64;
        live * self.head_instances() as f64 * flops::bwd_tile_flops(self.block, self.head_dim)
    }

    /// Calibrated base compute cost per tile (cycles).
    pub fn compute_cycles(&self) -> f64 {
        flops::bwd_tile_flops(self.block, self.head_dim) / h800::FLOPS_PER_CYCLE_PER_SM
    }

    /// Calibrated base reduction cost per tile (cycles): read-modify-write
    /// of a `block x head_dim` fp32 dQ tile through L2.
    pub fn reduce_cycles(&self) -> f64 {
        let bytes = 2.0 * (self.block * self.head_dim * 4) as f64;
        bytes / h800::L2_BYTES_PER_CYCLE_PER_SM
    }

    /// Cost model for a schedule kind (includes register-spill inflation).
    pub fn cost_model(&self, kind: ScheduleKind, l2: L2Model, reg: &RegisterModel) -> CostModel {
        CostModel {
            compute: self.compute_cycles(),
            reduce: self.reduce_cycles(),
            spill_factor: reg.spill_factor(kind, self.head_dim),
            l2,
        }
    }

    /// Co-resident CTAs per SM for this head dimension: the FA3 backward's
    /// SMEM footprint admits 2 CTAs at headdim <= 64, 1 at headdim 128.
    pub fn occupancy(&self) -> usize {
        if self.head_dim <= 64 {
            2
        } else {
            1
        }
    }

    /// Heads whose K/V working sets fit in L2 simultaneously — the
    /// interleave width of the L2-aware LPT chain scheduler. The LPT
    /// interleave is the *causal* kernel's scheduler (§4.3); full-mask
    /// grids launch in natural head-major order (uniform chains give LPT
    /// nothing to balance), so they report width 1.
    pub fn head_interleave(&self) -> usize {
        if self.mask == Mask::Full {
            return 1;
        }
        let footprint = self.seqlen * self.head_dim * 2 /* K+V */ * 2 /* bf16 */;
        (h800::L2_BYTES / footprint.max(1)).max(1)
    }

    /// Build the schedule of a given kind for this config. `sim` is the
    /// configuration the schedule will be *scored/executed* under — it
    /// drives the machine width for LPT placement and the cost model (and
    /// cache fingerprint) for tuned schedules.
    pub fn schedule(&self, kind: ScheduleKind, sim: &SimConfig) -> Schedule {
        let spec = self.spec();
        let w = self.head_interleave();
        match kind {
            ScheduleKind::Fa3 => crate::schedule::fa3::fa3_with_interleave(spec, true, w),
            ScheduleKind::Fa3Atomic => {
                crate::schedule::fa3::fa3_with_interleave(spec, false, w)
            }
            ScheduleKind::Descending => {
                crate::schedule::descending::descending_with_interleave(spec, w)
            }
            ScheduleKind::Shift => shift(spec),
            ScheduleKind::SymmetricShift => symmetric_shift(spec),
            ScheduleKind::TwoPass => two_pass(spec),
            ScheduleKind::Lpt => crate::schedule::lpt_schedule(spec, sim.n_sm),
            // Inline quick-tune (cache-first); full searches belong to
            // `dash tune`, which persists its results.
            ScheduleKind::Tuned => crate::autotune::tuned_schedule_for(spec, sim),
        }
    }
}

/// Simulated outcome for one (config, schedule) point.
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    /// Schedule evaluated.
    pub kind: ScheduleKind,
    /// Sequence length.
    pub seqlen: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Makespan, cycles.
    pub makespan_cycles: f64,
    /// Achieved TFLOPs/s on the modelled H800.
    pub tflops: f64,
    /// Utilization in [0,1].
    pub utilization: f64,
    /// Total reduction-stall cycles.
    pub stall_cycles: f64,
}

/// Run one figure point on the modelled H800.
pub fn run_point(
    config: &BenchConfig,
    kind: ScheduleKind,
    l2: L2Model,
    reg: &RegisterModel,
) -> WorkloadPoint {
    // FA3-realistic pipeline: async dQ-writer warp, 2-stage buffer,
    // co-residency from the SMEM footprint (2 CTAs/SM at hd64, 1 at hd128).
    let sim_cfg = SimConfig::fa3_pipeline(
        h800::N_SM,
        config.cost_model(kind, l2, reg),
        config.occupancy(),
    );
    let schedule = config.schedule(kind, &sim_cfg);
    let r: SimResult = simulate(&schedule, &sim_cfg).expect("legal schedules cannot deadlock");
    WorkloadPoint {
        kind,
        seqlen: config.seqlen,
        head_dim: config.head_dim,
        makespan_cycles: r.makespan,
        tflops: super::metrics::throughput_tflops(
            config.total_flops(),
            r.makespan,
            h800::CLOCK_GHZ,
        ),
        utilization: super::metrics::utilization(&r, h800::N_SM * config.occupancy()),
        stall_cycles: r.stall_time,
    }
}

/// The paper's x-axis: sequence lengths from 512 to 16,384.
pub const PAPER_SEQLENS: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_geometry() {
        let c = BenchConfig::paper(16384, 128, Mask::Causal);
        assert_eq!(c.n_tiles(), 128);
        assert_eq!(c.head_instances(), 16); // batch 1 x 16 heads
        let c2 = BenchConfig::paper(512, 64, Mask::Full);
        assert_eq!(c2.n_tiles(), 4);
        assert_eq!(c2.head_instances(), 32 * 32);
    }

    #[test]
    fn costs_scale_with_head_dim() {
        let a = BenchConfig::paper(2048, 64, Mask::Full);
        let b = BenchConfig::paper(2048, 128, Mask::Full);
        assert!((b.compute_cycles() / a.compute_cycles() - 2.0).abs() < 1e-9);
        assert!((b.reduce_cycles() / a.reduce_cycles() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reduce_is_fraction_of_compute() {
        // Calibration sanity: r/c should be well under 1 (compute-bound
        // tiles) but non-negligible (the whole paper exists because r
        // matters).
        let c = BenchConfig::paper(4096, 128, Mask::Causal);
        let ratio = c.reduce_cycles() / c.compute_cycles();
        assert!(ratio > 0.1 && ratio < 0.8, "r/c = {ratio}");
    }

    #[test]
    fn run_point_produces_finite_throughput() {
        let c = BenchConfig::paper(1024, 64, Mask::Full);
        let p = run_point(&c, ScheduleKind::Fa3, L2Model::ideal(), &RegisterModel::default());
        assert!(p.tflops > 0.0 && p.tflops.is_finite());
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
    }

    #[test]
    fn deterministic_not_faster_than_atomic() {
        let c = BenchConfig::paper(4096, 128, Mask::Causal);
        let reg = RegisterModel::default();
        let det = run_point(&c, ScheduleKind::Fa3, L2Model::default(), &reg);
        let atom = run_point(&c, ScheduleKind::Fa3Atomic, L2Model::default(), &reg);
        assert!(det.tflops <= atom.tflops + 1e-9);
    }
}
