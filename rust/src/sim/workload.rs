//! Benchmark workload definitions: the paper's §4.1 configurations mapped
//! onto simulator inputs (problem geometry + profile-calibrated cost
//! model).
//!
//! Methodology from the paper: total tokens fixed at 16,384, sequence
//! length swept 512..16,384, hidden dim 2,048, head dims {64, 128}, BF16,
//! KV/Q block size 128. The machine is no longer baked in: every cost,
//! occupancy, and interleave decision is derived from the
//! [`crate::hw::GpuProfile`] inside the [`Machine`] a caller passes
//! (`h800` reproduces the paper's setup; see [`crate::hw::presets`]).
//! The mask is a first-class [`MaskSpec`]: the same sweep machinery runs
//! full, causal, sliding-window, document, and sparse workloads.

use super::engine::{simulate, CostModel, SimConfig, SimResult};
use crate::hw::{GpuProfile, Machine};
use crate::schedule::{
    shift, symmetric_shift, two_pass, MaskSpec, ProblemSpec, Schedule, ScheduleError,
    ScheduleKind,
};

/// One benchmark configuration (a point on a figure's x-axis).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Sequence length (512..16,384).
    pub seqlen: usize,
    /// Fixed token budget; batch = total_tokens / seqlen.
    pub total_tokens: usize,
    /// Model hidden dimension (2,048 in the paper).
    pub hidden: usize,
    /// Attention head dimension (64 or 128).
    pub head_dim: usize,
    /// Tile size along both Q and KV (128 in FA3).
    pub block: usize,
    /// Mask shape.
    pub mask: MaskSpec,
}

impl BenchConfig {
    /// The paper's standard sweep point.
    pub fn paper(seqlen: usize, head_dim: usize, mask: MaskSpec) -> Self {
        Self { seqlen, total_tokens: 16384, hidden: 2048, head_dim, block: 128, mask }
    }

    /// KV (= Q) tiles per head.
    pub fn n_tiles(&self) -> usize {
        self.seqlen.div_ceil(self.block)
    }

    /// Independent head instances = batch x heads.
    pub fn head_instances(&self) -> usize {
        let batch = (self.total_tokens / self.seqlen).max(1);
        let heads = self.hidden / self.head_dim;
        batch * heads
    }

    /// Problem geometry for the simulator.
    pub fn spec(&self) -> ProblemSpec {
        ProblemSpec::square(self.n_tiles(), self.head_instances(), self.mask.clone())
    }

    /// Backward-pass FLOPs of the whole workload.
    pub fn total_flops(&self) -> f64 {
        let n = self.n_tiles();
        let live = self.mask.total_tiles(n, n) as f64;
        live * self.head_instances() as f64
            * crate::attention::flops::bwd_tile_flops(self.block, self.head_dim)
    }

    /// Cost model for a schedule kind on a machine: profile-calibrated
    /// compute/reduce cycles, the machine's L2 signalling model, and
    /// register-spill inflation.
    pub fn cost_model(&self, kind: ScheduleKind, m: &Machine) -> CostModel {
        CostModel {
            compute: m.profile.compute_cycles(self.block, self.head_dim),
            reduce: m.profile.reduce_cycles(self.block, self.head_dim),
            spill_factor: m.reg.spill_factor(kind, self.head_dim),
            l2: m.l2,
        }
    }

    /// FA3-pipeline simulator configuration for this point on a machine
    /// (async dQ-writer warp, 2-stage buffer, SMEM-derived co-residency,
    /// profile-fingerprinted for cache keying).
    pub fn sim_config(&self, kind: ScheduleKind, m: &Machine) -> SimConfig {
        m.sim_config(kind, self.n_tiles(), self.block, self.head_dim)
    }

    /// Build the schedule of a given kind for this config. `sim` is the
    /// configuration the schedule will be *scored/executed* under — it
    /// drives the machine width for LPT placement and the cost model (and
    /// cache fingerprint) for tuned schedules; `profile` drives the
    /// L2-aware head-interleave width. Structure-dependent generators
    /// (Shift) surface their typed [`ScheduleError`] instead of emitting
    /// an invalid schedule.
    pub fn schedule(
        &self,
        kind: ScheduleKind,
        sim: &SimConfig,
        profile: &GpuProfile,
    ) -> Result<Schedule, ScheduleError> {
        let spec = self.spec();
        let w = profile.head_interleave(self.seqlen, self.head_dim, &self.mask);
        Ok(match kind {
            ScheduleKind::Fa3 => crate::schedule::fa3::fa3_with_interleave(&spec, true, w),
            ScheduleKind::Fa3Atomic => {
                crate::schedule::fa3::fa3_with_interleave(&spec, false, w)
            }
            ScheduleKind::Descending => {
                crate::schedule::descending::descending_with_interleave(&spec, w)
            }
            ScheduleKind::Shift => shift(&spec)?,
            ScheduleKind::SymmetricShift => symmetric_shift(&spec),
            ScheduleKind::TwoPass => two_pass(&spec),
            ScheduleKind::Lpt => crate::schedule::lpt_schedule(&spec, sim.n_sm),
            // Inline quick-tune (cache-first); full searches belong to
            // `dash tune`, which persists its results.
            ScheduleKind::Tuned => crate::autotune::tuned_schedule_for(&spec, sim),
        })
    }
}

/// Simulated outcome for one (config, schedule, machine) point.
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    /// Schedule evaluated.
    pub kind: ScheduleKind,
    /// Sequence length.
    pub seqlen: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// SMs of the machine the point ran on.
    pub n_sm: usize,
    /// Makespan, cycles.
    pub makespan_cycles: f64,
    /// Achieved TFLOPs/s on the modelled machine.
    pub tflops: f64,
    /// Utilization in [0,1].
    pub utilization: f64,
    /// Total reduction-stall cycles.
    pub stall_cycles: f64,
}

/// Run one figure point on a modelled machine. Panics when asked for a
/// (schedule, mask) pair the generator rejects — the figure harness only
/// pairs Shift with full masks.
pub fn run_point(config: &BenchConfig, kind: ScheduleKind, m: &Machine) -> WorkloadPoint {
    let sim_cfg = config.sim_config(kind, m);
    let schedule = config
        .schedule(kind, &sim_cfg, &m.profile)
        .expect("figure harness pairs each schedule with a supported mask");
    let r: SimResult = simulate(&schedule, &sim_cfg).expect("legal schedules cannot deadlock");
    WorkloadPoint {
        kind,
        seqlen: config.seqlen,
        head_dim: config.head_dim,
        n_sm: sim_cfg.n_sm,
        makespan_cycles: r.makespan,
        tflops: super::metrics::throughput_tflops(
            config.total_flops(),
            r.makespan,
            m.profile.clock_ghz,
        ),
        utilization: super::metrics::utilization(&r, sim_cfg.n_sm * sim_cfg.occupancy),
        stall_cycles: r.stall_time,
    }
}

/// The paper's x-axis: sequence lengths from 512 to 16,384.
pub const PAPER_SEQLENS: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::sim::L2Model;

    fn h800_machine() -> Machine {
        Machine::real(presets::h800())
    }

    #[test]
    fn paper_config_geometry() {
        let c = BenchConfig::paper(16384, 128, MaskSpec::causal());
        assert_eq!(c.n_tiles(), 128);
        assert_eq!(c.head_instances(), 16); // batch 1 x 16 heads
        let c2 = BenchConfig::paper(512, 64, MaskSpec::full());
        assert_eq!(c2.n_tiles(), 4);
        assert_eq!(c2.head_instances(), 32 * 32);
    }

    #[test]
    fn costs_scale_with_head_dim() {
        let m = h800_machine();
        let a = BenchConfig::paper(2048, 64, MaskSpec::full());
        let b = BenchConfig::paper(2048, 128, MaskSpec::full());
        let ca = a.cost_model(ScheduleKind::Fa3, &m);
        let cb = b.cost_model(ScheduleKind::Fa3, &m);
        assert!((cb.compute / ca.compute - 2.0).abs() < 1e-9);
        assert!((cb.reduce / ca.reduce - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reduce_is_fraction_of_compute() {
        // Calibration sanity: r/c should be well under 1 (compute-bound
        // tiles) but non-negligible (the whole paper exists because r
        // matters).
        let c = BenchConfig::paper(4096, 128, MaskSpec::causal());
        let cost = c.cost_model(ScheduleKind::Fa3, &h800_machine());
        let ratio = cost.reduce / cost.compute;
        assert!(ratio > 0.1 && ratio < 0.8, "r/c = {ratio}");
    }

    #[test]
    fn run_point_produces_finite_throughput() {
        let c = BenchConfig::paper(1024, 64, MaskSpec::full());
        let mut m = h800_machine();
        m.l2 = L2Model::ideal();
        let p = run_point(&c, ScheduleKind::Fa3, &m);
        assert!(p.tflops > 0.0 && p.tflops.is_finite());
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        assert_eq!(p.n_sm, 132);
    }

    #[test]
    fn deterministic_not_faster_than_atomic() {
        let c = BenchConfig::paper(4096, 128, MaskSpec::causal());
        let m = h800_machine();
        let det = run_point(&c, ScheduleKind::Fa3, &m);
        let atom = run_point(&c, ScheduleKind::Fa3Atomic, &m);
        assert!(det.tflops <= atom.tflops + 1e-9);
    }

    #[test]
    fn sliding_window_and_document_points_run_end_to_end() {
        // The scenario-diversity acceptance: the same profile-calibrated
        // pipeline serves swa and varlen workloads.
        let m = h800_machine();
        for mask in [MaskSpec::sliding_window(4), MaskSpec::document(vec![4, 9])] {
            let c = BenchConfig::paper(2048, 64, mask);
            for kind in [ScheduleKind::Fa3, ScheduleKind::Descending, ScheduleKind::Lpt] {
                let p = run_point(&c, kind, &m);
                assert!(
                    p.makespan_cycles > 0.0 && p.tflops.is_finite(),
                    "{kind:?} on {:?}",
                    c.mask
                );
            }
        }
    }

    #[test]
    fn shift_on_a_non_full_mask_is_a_typed_error() {
        let c = BenchConfig::paper(1024, 64, MaskSpec::sliding_window(2));
        let m = h800_machine();
        let sim = c.sim_config(ScheduleKind::Shift, &m);
        assert!(matches!(
            c.schedule(ScheduleKind::Shift, &sim, &m.profile),
            Err(ScheduleError::UnsupportedMask { .. })
        ));
    }
}
