//! Benchmark workload definitions: the paper's §4.1 configurations mapped
//! onto simulator inputs (problem geometry + profile-calibrated cost
//! model).
//!
//! Methodology from the paper: total tokens fixed at 16,384, sequence
//! length swept 512..16,384, hidden dim 2,048, head dims {64, 128}, BF16,
//! KV/Q block size 128. The machine is no longer baked in: every cost,
//! occupancy, and interleave decision is derived from the
//! [`crate::hw::GpuProfile`] inside the [`Machine`] a caller passes
//! (`h800` reproduces the paper's setup; see [`crate::hw::presets`]).

use super::engine::{simulate, CostModel, SimConfig, SimResult};
use crate::hw::{GpuProfile, Machine};
use crate::schedule::{
    shift, symmetric_shift, two_pass, Mask, ProblemSpec, Schedule, ScheduleKind,
};

/// H800 machine constants — **deprecated**: the hardware description is
/// now a first-class input, [`crate::hw::GpuProfile`]; these constants are
/// kept for one release as a frozen mirror of [`crate::hw::presets::h800`]
/// and are consumed by nothing in-tree.
#[deprecated(note = "use crate::hw::presets::h800() — the GpuProfile preset — instead")]
pub mod h800 {
    /// Streaming multiprocessors.
    pub const N_SM: usize = 132;
    /// Boost clock, GHz.
    pub const CLOCK_GHZ: f64 = 1.98;
    /// Effective BF16 FLOPs per cycle per SM (dense tensor-core peak
    /// ~3,787/cycle derated to ~65% sustained MXU/WGMMA efficiency —
    /// FA3 reports ~75% of peak on H100 for the fwd pass; bwd is lower).
    pub const FLOPS_PER_CYCLE_PER_SM: f64 = 2460.0;
    /// Effective L2 bandwidth per SM, bytes/cycle, for dQ read-modify-write.
    pub const L2_BYTES_PER_CYCLE_PER_SM: f64 = 32.0;
    /// L2 cache capacity (H800: 50 MiB).
    pub const L2_BYTES: usize = 50 * 1024 * 1024;
}

/// One benchmark configuration (a point on a figure's x-axis).
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Sequence length (512..16,384).
    pub seqlen: usize,
    /// Fixed token budget; batch = total_tokens / seqlen.
    pub total_tokens: usize,
    /// Model hidden dimension (2,048 in the paper).
    pub hidden: usize,
    /// Attention head dimension (64 or 128).
    pub head_dim: usize,
    /// Tile size along both Q and KV (128 in FA3).
    pub block: usize,
    /// Mask shape.
    pub mask: Mask,
}

impl BenchConfig {
    /// The paper's standard sweep point.
    pub fn paper(seqlen: usize, head_dim: usize, mask: Mask) -> Self {
        Self { seqlen, total_tokens: 16384, hidden: 2048, head_dim, block: 128, mask }
    }

    /// KV (= Q) tiles per head.
    pub fn n_tiles(&self) -> usize {
        self.seqlen.div_ceil(self.block)
    }

    /// Independent head instances = batch x heads.
    pub fn head_instances(&self) -> usize {
        let batch = (self.total_tokens / self.seqlen).max(1);
        let heads = self.hidden / self.head_dim;
        batch * heads
    }

    /// Problem geometry for the simulator.
    pub fn spec(&self) -> ProblemSpec {
        ProblemSpec::square(self.n_tiles(), self.head_instances(), self.mask)
    }

    /// Backward-pass FLOPs of the whole workload.
    pub fn total_flops(&self) -> f64 {
        let live = self.mask.total_tiles(self.n_tiles(), self.n_tiles()) as f64;
        live * self.head_instances() as f64
            * crate::attention::flops::bwd_tile_flops(self.block, self.head_dim)
    }

    /// Cost model for a schedule kind on a machine: profile-calibrated
    /// compute/reduce cycles, the machine's L2 signalling model, and
    /// register-spill inflation.
    pub fn cost_model(&self, kind: ScheduleKind, m: &Machine) -> CostModel {
        CostModel {
            compute: m.profile.compute_cycles(self.block, self.head_dim),
            reduce: m.profile.reduce_cycles(self.block, self.head_dim),
            spill_factor: m.reg.spill_factor(kind, self.head_dim),
            l2: m.l2,
        }
    }

    /// FA3-pipeline simulator configuration for this point on a machine
    /// (async dQ-writer warp, 2-stage buffer, SMEM-derived co-residency,
    /// profile-fingerprinted for cache keying).
    pub fn sim_config(&self, kind: ScheduleKind, m: &Machine) -> SimConfig {
        m.sim_config(kind, self.n_tiles(), self.block, self.head_dim)
    }

    /// Build the schedule of a given kind for this config. `sim` is the
    /// configuration the schedule will be *scored/executed* under — it
    /// drives the machine width for LPT placement and the cost model (and
    /// cache fingerprint) for tuned schedules; `profile` drives the
    /// L2-aware head-interleave width.
    pub fn schedule(&self, kind: ScheduleKind, sim: &SimConfig, profile: &GpuProfile) -> Schedule {
        let spec = self.spec();
        let w = profile.head_interleave(self.seqlen, self.head_dim, self.mask);
        match kind {
            ScheduleKind::Fa3 => crate::schedule::fa3::fa3_with_interleave(spec, true, w),
            ScheduleKind::Fa3Atomic => {
                crate::schedule::fa3::fa3_with_interleave(spec, false, w)
            }
            ScheduleKind::Descending => {
                crate::schedule::descending::descending_with_interleave(spec, w)
            }
            ScheduleKind::Shift => shift(spec),
            ScheduleKind::SymmetricShift => symmetric_shift(spec),
            ScheduleKind::TwoPass => two_pass(spec),
            ScheduleKind::Lpt => crate::schedule::lpt_schedule(spec, sim.n_sm),
            // Inline quick-tune (cache-first); full searches belong to
            // `dash tune`, which persists its results.
            ScheduleKind::Tuned => crate::autotune::tuned_schedule_for(spec, sim),
        }
    }
}

/// Simulated outcome for one (config, schedule, machine) point.
#[derive(Debug, Clone)]
pub struct WorkloadPoint {
    /// Schedule evaluated.
    pub kind: ScheduleKind,
    /// Sequence length.
    pub seqlen: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// SMs of the machine the point ran on.
    pub n_sm: usize,
    /// Makespan, cycles.
    pub makespan_cycles: f64,
    /// Achieved TFLOPs/s on the modelled machine.
    pub tflops: f64,
    /// Utilization in [0,1].
    pub utilization: f64,
    /// Total reduction-stall cycles.
    pub stall_cycles: f64,
}

/// Run one figure point on a modelled machine.
pub fn run_point(config: &BenchConfig, kind: ScheduleKind, m: &Machine) -> WorkloadPoint {
    let sim_cfg = config.sim_config(kind, m);
    let schedule = config.schedule(kind, &sim_cfg, &m.profile);
    let r: SimResult = simulate(&schedule, &sim_cfg).expect("legal schedules cannot deadlock");
    WorkloadPoint {
        kind,
        seqlen: config.seqlen,
        head_dim: config.head_dim,
        n_sm: sim_cfg.n_sm,
        makespan_cycles: r.makespan,
        tflops: super::metrics::throughput_tflops(
            config.total_flops(),
            r.makespan,
            m.profile.clock_ghz,
        ),
        utilization: super::metrics::utilization(&r, sim_cfg.n_sm * sim_cfg.occupancy),
        stall_cycles: r.stall_time,
    }
}

/// The paper's x-axis: sequence lengths from 512 to 16,384.
pub const PAPER_SEQLENS: [usize; 6] = [512, 1024, 2048, 4096, 8192, 16384];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;
    use crate::sim::L2Model;

    fn h800_machine() -> Machine {
        Machine::real(presets::h800())
    }

    #[test]
    fn paper_config_geometry() {
        let c = BenchConfig::paper(16384, 128, Mask::Causal);
        assert_eq!(c.n_tiles(), 128);
        assert_eq!(c.head_instances(), 16); // batch 1 x 16 heads
        let c2 = BenchConfig::paper(512, 64, Mask::Full);
        assert_eq!(c2.n_tiles(), 4);
        assert_eq!(c2.head_instances(), 32 * 32);
    }

    #[test]
    fn costs_scale_with_head_dim() {
        let m = h800_machine();
        let a = BenchConfig::paper(2048, 64, Mask::Full);
        let b = BenchConfig::paper(2048, 128, Mask::Full);
        let ca = a.cost_model(ScheduleKind::Fa3, &m);
        let cb = b.cost_model(ScheduleKind::Fa3, &m);
        assert!((cb.compute / ca.compute - 2.0).abs() < 1e-9);
        assert!((cb.reduce / ca.reduce - 2.0).abs() < 1e-9);
    }

    #[test]
    fn reduce_is_fraction_of_compute() {
        // Calibration sanity: r/c should be well under 1 (compute-bound
        // tiles) but non-negligible (the whole paper exists because r
        // matters).
        let c = BenchConfig::paper(4096, 128, Mask::Causal);
        let cost = c.cost_model(ScheduleKind::Fa3, &h800_machine());
        let ratio = cost.reduce / cost.compute;
        assert!(ratio > 0.1 && ratio < 0.8, "r/c = {ratio}");
    }

    #[test]
    fn run_point_produces_finite_throughput() {
        let c = BenchConfig::paper(1024, 64, Mask::Full);
        let mut m = h800_machine();
        m.l2 = L2Model::ideal();
        let p = run_point(&c, ScheduleKind::Fa3, &m);
        assert!(p.tflops > 0.0 && p.tflops.is_finite());
        assert!(p.utilization > 0.0 && p.utilization <= 1.0);
        assert_eq!(p.n_sm, 132);
    }

    #[test]
    fn deterministic_not_faster_than_atomic() {
        let c = BenchConfig::paper(4096, 128, Mask::Causal);
        let m = h800_machine();
        let det = run_point(&c, ScheduleKind::Fa3, &m);
        let atom = run_point(&c, ScheduleKind::Fa3Atomic, &m);
        assert!(det.tflops <= atom.tflops + 1e-9);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_h800_module_mirrors_the_preset() {
        let p = presets::h800();
        assert_eq!(p.n_sm, h800::N_SM);
        assert_eq!(p.clock_ghz, h800::CLOCK_GHZ);
        assert_eq!(p.flops_per_cycle_per_sm, h800::FLOPS_PER_CYCLE_PER_SM);
        assert_eq!(p.l2_bytes_per_cycle_per_sm, h800::L2_BYTES_PER_CYCLE_PER_SM);
        assert_eq!(p.l2_bytes, h800::L2_BYTES);
    }
}
