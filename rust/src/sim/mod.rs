//! Discrete-event simulator of the deterministic attention backward pass on
//! a datacenter-class GPU — the substrate that regenerates every figure in
//! the paper (see the top-level README.md for the substitution argument).
//! The machine itself is an input: costs, occupancy, and L2 behaviour are
//! derived from a [`crate::hw::GpuProfile`] (the `h800` preset reproduces
//! the paper's setup).
//!
//! The model follows the paper's §3.1 abstraction — per-SM serial chains of
//! (compute `c`, reduction `r`) phases with a serialized per-dQ accumulation
//! order — extended with the two hardware effects §4 identifies as decisive:
//! segmented-L2 signalling latency ([`l2`]) and register-pressure spills
//! ([`regpressure`]). Chains are either pinned (shift-style schedules) or
//! pulled dynamically from the launch-ordered grid queue (persistent-CTA
//! work stealing, the FA3 behaviour).

mod engine;
mod gantt;
pub mod l2;
pub mod metrics;
pub mod regpressure;
pub mod workload;

pub use engine::{
    simulate, simulate_batch, CostModel, LinkSpan, SimConfig, SimError, SimResult, Simulator,
    TaskSpan,
};
pub use gantt::{cluster_lane_labels, render_gantt, render_gantt_cluster, render_gantt_csv};
pub use l2::L2Model;
pub use metrics::{stall_fraction, throughput_tflops, utilization};
pub use regpressure::RegisterModel;
pub use workload::{BenchConfig, WorkloadPoint};
