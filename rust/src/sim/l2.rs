//! Segmented-L2 inter-SM signalling model (§2.2 / §4.2).
//!
//! Datacenter-class GPUs physically segment the L2 cache; each segment
//! preferentially serves a subset of SMs, and remote-segment accesses cost
//! 2.5x+ a local access (≈200 vs ≈500+ cycles on H800-class parts, Luo et
//! al. 2025). Deterministic accumulation serializes dQ reductions across
//! SMs, so every hand-over of the "your turn" token is an L2 round trip —
//! this latency is the paper's explanation for Shift Scheduling losing to
//! the baseline at seqlen 16,384 (Fig 8).


/// L2 signalling-latency model. Latencies are in cycles.
#[derive(Debug, Clone, Copy)]
pub struct L2Model {
    /// Number of physical L2 segments (H100/H800: 2 partitions x banks; we
    /// default to 4 effective locality domains).
    pub n_segments: usize,
    /// Same-segment signal latency (cycles).
    pub local_latency: f64,
    /// Cross-segment signal latency (cycles).
    pub remote_latency: f64,
}

impl Default for L2Model {
    fn default() -> Self {
        // H800 microbenchmark numbers from the paper (§4.2): ~200 local,
        // 500+ remote. Profile-driven code paths build this from
        // `crate::hw::GpuProfile::l2_model` instead; the default exists for
        // the abstract-machine `--l2` knob and hand-built configs.
        Self { n_segments: 4, local_latency: 200.0, remote_latency: 500.0 }
    }
}

impl L2Model {
    /// An idealized zero-latency interconnect (the paper's DAG model).
    pub fn ideal() -> Self {
        Self { n_segments: 1, local_latency: 0.0, remote_latency: 0.0 }
    }

    /// Segment that SM `sm` of `n_sm` hangs off. Clamped into
    /// `0..n_segments` even for out-of-range `sm` (callers occasionally
    /// probe with logical slot ids >= `n_sm`; the old unclamped division
    /// returned a segment index past the last physical segment).
    pub fn segment_of(&self, sm: usize, n_sm: usize) -> usize {
        let segs = self.n_segments.max(1);
        if n_sm == 0 {
            return 0;
        }
        (sm * segs / n_sm).min(segs - 1)
    }

    /// Latency for a completion signal from `src` SM to `dst` SM.
    pub fn signal_latency(&self, src: usize, dst: usize, n_sm: usize) -> f64 {
        if src == dst {
            // Same SM: the token never leaves the SM (register/smem).
            0.0
        } else if self.segment_of(src, n_sm) == self.segment_of(dst, n_sm) {
            self.local_latency
        } else {
            self.remote_latency
        }
    }

    /// Expected signal latency between two uniformly-random distinct SMs —
    /// used by the analytic model to sanity-check the simulator.
    pub fn mean_latency(&self, n_sm: usize) -> f64 {
        if n_sm <= 1 {
            return 0.0;
        }
        let mut total = 0.0;
        let mut pairs = 0usize;
        for a in 0..n_sm {
            for b in 0..n_sm {
                if a != b {
                    total += self.signal_latency(a, b, n_sm);
                    pairs += 1;
                }
            }
        }
        total / pairs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_sm_is_free() {
        let m = L2Model::default();
        assert_eq!(m.signal_latency(3, 3, 8), 0.0);
    }

    #[test]
    fn neighbors_in_segment_are_local() {
        let m = L2Model::default();
        // 8 SMs, 4 segments -> SMs 0,1 share segment 0.
        assert_eq!(m.signal_latency(0, 1, 8), 200.0);
        assert_eq!(m.signal_latency(0, 7, 8), 500.0);
    }

    #[test]
    fn ideal_model_is_zero() {
        let m = L2Model::ideal();
        assert_eq!(m.signal_latency(0, 131, 132), 0.0);
    }

    #[test]
    fn mean_latency_between_local_and_remote() {
        let m = L2Model::default();
        let mean = m.mean_latency(132);
        assert!(mean > m.local_latency && mean < m.remote_latency);
    }

    #[test]
    fn segment_of_is_clamped_for_out_of_range_sms() {
        let m = L2Model::default();
        // sm >= n_sm used to index a segment past the last one.
        assert_eq!(m.segment_of(8, 8), m.n_segments - 1);
        assert_eq!(m.segment_of(1000, 8), m.n_segments - 1);
        assert_eq!(m.segment_of(7, 8), m.n_segments - 1);
        // In-range mapping is untouched.
        assert_eq!(m.segment_of(0, 8), 0);
        for sm in 0..8 {
            assert!(m.segment_of(sm, 8) < m.n_segments);
        }
        // Degenerate models stay in range too.
        let one = L2Model { n_segments: 0, ..L2Model::default() };
        assert_eq!(one.segment_of(5, 8), 0);
    }

    #[test]
    fn more_segments_raise_remote_fraction() {
        // Finer L2 segmentation makes a larger share of SM pairs remote.
        let coarse = L2Model { n_segments: 2, ..L2Model::default() };
        let fine = L2Model { n_segments: 8, ..L2Model::default() };
        assert!(fine.mean_latency(132) > coarse.mean_latency(132));
    }
}
