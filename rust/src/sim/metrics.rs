//! Throughput/utilization metrics converting simulated makespans into the
//! units the paper plots (TFLOPs/s of backward-pass work), plus the
//! stall-fraction metric derived from the trace layer ([`crate::trace`]).

use super::engine::SimResult;
use crate::trace::SimTrace;

/// Convert a simulated makespan into achieved TFLOPs/s.
///
/// * `total_flops` — backward-pass FLOPs of the whole workload
///   (from [`crate::attention::flops`]).
/// * `makespan_cycles` — simulated makespan.
/// * `clock_ghz` — SM clock (H800 boost ≈ 1.98 GHz).
///
/// Degenerate inputs (zero/negative makespan or clock, non-finite clock)
/// return 0.0 rather than NaN/Inf — a sweep over an empty workload must
/// tabulate, not poison downstream figures.
pub fn throughput_tflops(total_flops: f64, makespan_cycles: f64, clock_ghz: f64) -> f64 {
    if makespan_cycles <= 0.0 || clock_ghz <= 0.0 || !clock_ghz.is_finite() {
        return 0.0;
    }
    let seconds = makespan_cycles / (clock_ghz * 1e9);
    total_flops / seconds / 1e12
}

/// Machine utilization of a result on an `n_sm` machine (idle SMs count).
/// Returns 0.0 for zero-makespan or zero-SM inputs.
pub fn utilization(result: &SimResult, n_sm: usize) -> f64 {
    if result.makespan <= 0.0 || n_sm == 0 {
        return 0.0;
    }
    result.busy_time / (result.makespan * n_sm as f64)
}

/// Fraction of the trace's lane-time budget spent stalled on the
/// serialized reduction order (token stalls plus their L2 tails) — the
/// paper's determinism cost as a single number in `[0, 1]`. Returns 0.0
/// for empty or zero-makespan traces.
pub fn stall_fraction(trace: &SimTrace) -> f64 {
    let lanes = trace.lanes_used();
    if trace.makespan <= 0.0 || lanes == 0 {
        return 0.0;
    }
    let t = trace.totals();
    (t.stall + t.l2) / (trace.makespan * lanes as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, shift, MaskSpec, ProblemSpec};
    use crate::sim::SimConfig;
    use crate::trace::{trace_simulation, TraceSource};

    #[test]
    fn throughput_scales_inversely_with_time() {
        let a = throughput_tflops(1e12, 1e9, 1.0);
        let b = throughput_tflops(1e12, 2e9, 1.0);
        assert!((a - 2.0 * b).abs() < 1e-9);
        assert!((a - 1.0).abs() < 1e-9); // 1e12 flops in 1s = 1 TFLOPs
    }

    #[test]
    fn degenerate_inputs_are_guarded() {
        assert_eq!(throughput_tflops(1e12, 0.0, 1.0), 0.0);
        assert_eq!(throughput_tflops(1e12, -5.0, 1.0), 0.0);
        assert_eq!(throughput_tflops(1e12, 1e9, 0.0), 0.0);
        assert_eq!(throughput_tflops(1e12, 1e9, -1.0), 0.0);
        assert_eq!(throughput_tflops(1e12, 1e9, f64::NAN), 0.0);
        assert_eq!(throughput_tflops(1e12, 1e9, f64::INFINITY), 0.0);
        let empty = SimResult {
            makespan: 0.0,
            busy_time: 0.0,
            reduce_busy: 0.0,
            stall_time: 0.0,
            n_tasks: 0,
            n_sm_used: 0,
            spans: Vec::new(),
            links: Vec::new(),
        };
        assert_eq!(utilization(&empty, 8), 0.0);
        assert_eq!(utilization(&empty, 0), 0.0);
    }

    #[test]
    fn stall_fraction_is_zero_for_stall_free_schedules() {
        let spec = ProblemSpec::square(4, 2, MaskSpec::full());
        let tr = trace_simulation(&shift(&spec).unwrap(), &SimConfig::ideal(4)).unwrap();
        assert_eq!(stall_fraction(&tr), 0.0);
        let empty = SimTrace {
            schedule: "none".into(),
            mask: "full".into(),
            n_kv: 0,
            n_q: 0,
            n_heads: 0,
            source: TraceSource::Sim,
            n_lanes: 0,
            makespan: 0.0,
            events: Vec::new(),
            lane_labels: Vec::new(),
        };
        assert_eq!(stall_fraction(&empty), 0.0);
    }

    #[test]
    fn stall_fraction_matches_the_engine_stall_accounting() {
        let spec = ProblemSpec::square(6, 2, MaskSpec::full());
        let s = fa3(&spec, true);
        let mut cfg = SimConfig::ideal(6);
        cfg.record_spans = true;
        let r = crate::sim::simulate(&s, &cfg).unwrap();
        let tr = crate::trace::trace_from_sim(&s, &cfg, &r);
        let t = tr.totals();
        assert!(
            (t.stall + t.l2 - r.stall_time).abs() < 1e-9,
            "trace stall {} + l2 {} != engine stall_time {}",
            t.stall,
            t.l2,
            r.stall_time
        );
        let f = stall_fraction(&tr);
        assert!(f > 0.0 && f < 1.0, "fa3 on the ideal machine stalls: {f}");
    }
}
