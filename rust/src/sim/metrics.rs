//! Throughput/utilization metrics converting simulated makespans into the
//! units the paper plots (TFLOPs/s of backward-pass work).

use super::engine::SimResult;

/// Convert a simulated makespan into achieved TFLOPs/s.
///
/// * `total_flops` — backward-pass FLOPs of the whole workload
///   (from [`crate::attention::flops`]).
/// * `makespan_cycles` — simulated makespan.
/// * `clock_ghz` — SM clock (H800 boost ≈ 1.98 GHz).
pub fn throughput_tflops(total_flops: f64, makespan_cycles: f64, clock_ghz: f64) -> f64 {
    if makespan_cycles <= 0.0 {
        return 0.0;
    }
    let seconds = makespan_cycles / (clock_ghz * 1e9);
    total_flops / seconds / 1e12
}

/// Machine utilization of a result on an `n_sm` machine (idle SMs count).
pub fn utilization(result: &SimResult, n_sm: usize) -> f64 {
    if result.makespan <= 0.0 || n_sm == 0 {
        return 0.0;
    }
    result.busy_time / (result.makespan * n_sm as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_scales_inversely_with_time() {
        let a = throughput_tflops(1e12, 1e9, 1.0);
        let b = throughput_tflops(1e12, 2e9, 1.0);
        assert!((a - 2.0 * b).abs() < 1e-9);
        assert!((a - 1.0).abs() < 1e-9); // 1e12 flops in 1s = 1 TFLOPs
    }

    #[test]
    fn zero_makespan_guarded() {
        assert_eq!(throughput_tflops(1e12, 0.0, 1.0), 0.0);
    }
}
