//! # DASH — Deterministic Attention Scheduling for High-throughput Reproducible LLM Training
//!
//! Full-stack reproduction of the DASH paper (Qiang et al., 2026) as a
//! four-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1** (build-time Python): Pallas flash-attention forward/backward
//!   kernels whose dQ accumulation order is an explicit, schedule-controlled
//!   input — the kernel-level embodiment of deterministic attention.
//! * **Layer 2** (build-time Python): a JAX transformer model whose attention
//!   uses the L1 kernels; lowered once to HLO text artifacts.
//! * **Layer 3** (this crate, [`hw`]): the hardware-profile layer — a
//!   swappable [`hw::GpuProfile`] (presets `h800`/`h100`/`a100`/`abstract`
//!   plus JSON-loadable custom parts) from which every simulator input is
//!   derived, so no stage names a concrete GPU.
//! * **Layer 4** (this crate): the mask layer ([`mask`]: full, causal,
//!   sliding-window, document/varlen, block-sparse — the innermost type of
//!   the pipeline), the scheduling theory ([`dag`], [`schedule`]),
//!   the profile-driven execution-model simulator ([`sim`]) that regenerates
//!   every figure in the paper, a search-based schedule autotuner with a
//!   persistent, profile-keyed tuning cache ([`autotune`]), floating-point
//!   reduction-order experiments ([`numerics`]), a PJRT runtime (`runtime`,
//!   behind the `pjrt` feature) that loads the AOT artifacts, and a
//!   deterministic training coordinator ([`coordinator`]).
//! * **Layer 5** (this crate, [`exec`]): the numeric determinism oracle —
//!   a tile-level reference executor that *runs* the attention backward
//!   pass in software (f32 / bf16) following any schedule, folds dQ
//!   through the schedule's reduction order, and content-hashes the
//!   gradients, so "deterministic" is a bitwise-verified property rather
//!   than a label (`dash verify`).
//! * **Serving layer** (this crate, [`traceload`]): deterministic
//!   request-trace generation (Zipf/log-normal lengths, Poisson/bursty
//!   arrivals, replayable from one seed) and a continuous-batching
//!   compiler that folds every serving step into an ordinary
//!   [`schedule::ProblemSpec`] under a document mask, with per-request
//!   batch invariance proved by the exec oracle (`dash trace`).
//! * **Observability** (this crate, [`trace`]): typed, content-hashed
//!   event traces of both engines, rendered as interactive timelines and
//!   stall flamegraphs, with CI-gated performance baselines
//!   (`dash timeline` / `flamegraph` / `baseline`).
//!
//! The paper's headline claims reproduced here:
//!
//! 1. Deterministic FA3 loses up to ~38% backward throughput (Fig 1) because
//!    the tile schedule conflicts with the fixed accumulation order.
//! 2. Modelling the backward pass as a DAG and minimizing critical path
//!    (Lemma 1: zero-weight dependency edges preserve the critical path iff
//!    depth-monotone) yields schedules — Descending Q-Tile, Shift, Symmetric
//!    Shift — that recover most of the gap (Figs 3–9).
//! 3. Determinism gives bitwise-identical gradients, non-determinism gives
//!    O(1e-4) run-to-run deviation (Table 1).
//!
//! See the top-level `README.md` for the build and a quick tour,
//! `docs/ARCHITECTURE.md` for the full layer map, data flow, and
//! invariants, and `docs/CLI.md` for the complete command reference.

#![warn(missing_docs)]

pub mod attention;
pub mod autotune;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod dag;
pub mod exec;
pub mod hw;
pub mod mask;
pub mod numerics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod trace;
pub mod traceload;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
