//! Bench: regenerate Table 1 — max gradient deviation over 10 identical
//! backward passes, deterministic vs non-deterministic accumulation —
//! and time the reduction kernels themselves.

use dash::bench_harness::{render_table, table1_determinism};
use dash::numerics::{kahan_sum, pairwise_sum, sum_in_order};
use dash::util::{BenchTimer, DetRng};

fn main() {
    println!("== Table 1: gradient deviation over 10 runs ==");
    println!("{}", render_table(&table1_determinism(10, 42)));

    let mut rng = DetRng::new(7);
    let values: Vec<f32> = (0..65536).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    let mut t = BenchTimer::new("table1");
    t.bench("sum_in_order/64k", || {
        std::hint::black_box(sum_in_order(&values));
    });
    t.bench("pairwise_sum/64k", || {
        std::hint::black_box(pairwise_sum(&values));
    });
    t.bench("kahan_sum/64k", || {
        std::hint::black_box(kahan_sum(&values));
    });
    t.finish();
}
