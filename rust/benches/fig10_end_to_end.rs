//! Bench: regenerate Figure 10 — end-to-end transformer-block speedups
//! (10a) and kernel-time breakdown (10b) over the paper's model zoo; when
//! AOT artifacts are present, also time the *real* PJRT transformer block
//! step (fwd+bwd+update) as the measured counterpart.

use dash::bench_harness::{fig10a_end_to_end, fig10b_breakdown, render_table};
use dash::coordinator::{TrainConfig, Trainer};
use dash::hw::{presets, Machine};
use dash::runtime::ArtifactManifest;
use dash::util::BenchTimer;

fn main() {
    let machine = Machine::real(presets::h800());

    println!(
        "== Figure 10a: end-to-end block speedup (modelled {}) ==",
        machine.profile.name
    );
    println!("{}", render_table(&fig10a_end_to_end(&machine)));
    println!(
        "== Figure 10b: kernel time breakdown (modelled {}) ==",
        machine.profile.name
    );
    println!("{}", render_table(&fig10b_breakdown(&machine)));

    // Measured counterpart on this machine (CPU PJRT), if artifacts exist.
    if ArtifactManifest::available("artifacts") {
        let cfg = TrainConfig { steps: 1, ..TrainConfig::default() };
        match Trainer::new(cfg) {
            Ok(mut trainer) => {
                let mut step = 0usize;
                // Warm the executable cache.
                trainer.step(step).expect("train step");
                let mut t = BenchTimer::new("fig10-measured");
                t.target_seconds = 3.0;
                t.bench("train_step/default-model", || {
                    step += 1;
                    trainer.step(step).expect("train step");
                });
                t.finish();
            }
            Err(e) => println!("(skipping measured block step: {e:#})"),
        }
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the measured block step)");
    }
}
