//! Bench: the autotuner — tuned-vs-analytic sweep over the acceptance grid
//! (masks {full, causal} x n {8,16,24,32} x n_sm {4,8,13}) plus timing of
//! the search loop itself on a representative off-regime point.

use dash::autotune::{tune, TuneOptions};
use dash::bench_harness::{render_table, tune_sweep};
use dash::schedule::{MaskSpec, ProblemSpec};
use dash::sim::SimConfig;
use dash::util::BenchTimer;

fn main() {
    println!("== Autotuner: tuned vs best analytic (ideal machine, heads=4) ==");
    let rows = tune_sweep(4, 300, 42);
    println!("{}", render_table(&rows));
    let wins = rows.iter().filter(|r| r.speedup > 1.0 + 1e-9).count();
    let optimal = rows.iter().filter(|r| r.gap_pct < 1e-6).count();
    println!(
        "{} points: {wins} strict wins over analytic, {optimal} certified optimal\n",
        rows.len()
    );

    // Search-loop throughput on an off-regime point (odd n, n_sm = 13).
    let spec = ProblemSpec::square(9, 4, MaskSpec::causal());
    let mut t = BenchTimer::new("tune");
    t.bench("tune/n9/m4/causal/sm13/budget100", || {
        let opts =
            TuneOptions { budget: 100, seed: 1, sim: SimConfig::ideal(13), batch: 1, threads: 1 };
        std::hint::black_box(tune(&spec, &opts).unwrap());
    });
    t.finish();
}
