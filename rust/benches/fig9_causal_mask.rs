//! Bench: regenerate Figure 9 — causal-mask backward throughput for
//! {FA3 baseline, Descending, Symmetric Shift, two-pass Triton-style}.

use dash::bench_harness::{fig9_causal_mask, render_table};
use dash::hw::{presets, Machine};
use dash::schedule::{MaskSpec, ScheduleKind};
use dash::sim::workload::{run_point, BenchConfig};
use dash::util::BenchTimer;

fn main() {
    let machine = Machine::real(presets::h800());

    let rows = fig9_causal_mask(&machine);
    println!(
        "== Figure 9: causal-mask backward throughput ({}) ==",
        machine.profile.name
    );
    println!("{}", render_table(&rows));

    let mut t = BenchTimer::new("fig9");
    for kind in [
        ScheduleKind::Fa3,
        ScheduleKind::Descending,
        ScheduleKind::SymmetricShift,
        ScheduleKind::TwoPass,
    ] {
        let cfg = BenchConfig::paper(8192, 64, MaskSpec::causal());
        t.bench(&format!("sim/{}/seq8192/hd64", kind.name()), || {
            std::hint::black_box(run_point(&cfg, kind, &machine));
        });
    }
    t.finish();
}
