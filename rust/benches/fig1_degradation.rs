//! Bench: regenerate Figure 1 (right) — deterministic-vs-atomic FA3
//! degradation — and time the underlying simulator points.

use dash::bench_harness::{fig1_degradation, render_table};
use dash::hw::{presets, Machine};
use dash::schedule::{MaskSpec, ScheduleKind};
use dash::sim::workload::{run_point, BenchConfig};
use dash::util::BenchTimer;

fn main() {
    let machine = Machine::real(presets::h800());

    // The figure itself.
    let rows = fig1_degradation(&machine);
    println!(
        "== Figure 1 (right): deterministic-mode degradation ({}) ==",
        machine.profile.name
    );
    println!("{}", render_table(&rows));

    // Timing of the heaviest sim points (hot-path health metric).
    let mut t = BenchTimer::new("fig1");
    for &(seqlen, hd) in &[(4096usize, 64usize), (16384, 128)] {
        for mask in [MaskSpec::causal(), MaskSpec::full()] {
            let name = mask.name();
            let cfg = BenchConfig::paper(seqlen, hd, mask);
            t.bench(&format!("sim/{name}/seq{seqlen}/hd{hd}"), || {
                std::hint::black_box(run_point(&cfg, ScheduleKind::Fa3, &machine));
            });
        }
    }
    t.finish();
}
