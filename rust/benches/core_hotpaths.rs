//! Bench: core hot paths — simulator event engine, schedule generation,
//! DAG critical path, LPT assignment. Track these numbers across perf PRs.
//!
//! The second half is the hot-path trajectory: repeated simulation at
//! n >= 256 through each engine entry point (fresh allocation per call,
//! one reused `Simulator`, `simulate_batch` across cores) and an
//! equal-budget serial-vs-batched tune — the measurements behind the
//! speedup claims in `BENCH_core.json` (see `dash baseline --suite core`).

use dash::autotune::{tune, TuneOptions};
use dash::dag::{build_schedule_dag, DagBuildOptions};
use dash::schedule::{
    descending, fa3, lpt::assign_lpt, shift, symmetric_shift, MaskSpec, ProblemSpec, Schedule,
};
use dash::sim::{simulate, simulate_batch, SimConfig, Simulator};
use dash::util::BenchTimer;

fn main() {
    let mut t = BenchTimer::new("core");

    // Schedule generation.
    let spec_big = ProblemSpec::square(128, 32, MaskSpec::causal());
    t.bench("gen/fa3/n128/m32", || {
        std::hint::black_box(fa3(&spec_big, true));
    });
    t.bench("gen/symshift/n128/m32", || {
        std::hint::black_box(symmetric_shift(&spec_big));
    });

    // Simulator engine throughput (tasks/sec implied by time).
    let s_causal = fa3(&spec_big, true);
    let cfg = SimConfig::ideal(132);
    t.bench("sim/fa3-causal/n128/m32 (69k tasks)", || {
        std::hint::black_box(simulate(&s_causal, &cfg).unwrap());
    });
    let s_desc = descending(&spec_big);
    t.bench("sim/descending/n128/m32", || {
        std::hint::black_box(simulate(&s_desc, &cfg).unwrap());
    });
    let spec_full = ProblemSpec::square(128, 16, MaskSpec::full());
    let s_shift = shift(&spec_full).unwrap();
    t.bench("sim/shift-full/n128/m16", || {
        std::hint::black_box(simulate(&s_shift, &cfg).unwrap());
    });

    // DAG critical path.
    t.bench("dag/build+cp/fa3/n128/m32", || {
        let d = build_schedule_dag(&s_causal, 128, DagBuildOptions::default());
        std::hint::black_box(d.makespan());
    });

    // LPT assignment.
    t.bench("lpt/assign/n128/m32/132sm", || {
        std::hint::black_box(assign_lpt(&s_causal, 132, 4, 0.5));
    });

    // Large single-shot grids: the n >= 256 regime the tuner and the
    // sweep harnesses live in.
    let spec_256 = ProblemSpec::square(256, 2, MaskSpec::causal());
    let s_256 = symmetric_shift(&spec_256);
    let cfg_256 = SimConfig::ideal(256);
    t.bench("sim/symshift-causal/n256/m2 (66k tasks)", || {
        std::hint::black_box(simulate(&s_256, &cfg_256).unwrap());
    });
    let spec_512 = ProblemSpec::square(512, 2, MaskSpec::full());
    let s_512 = shift(&spec_512).unwrap();
    let cfg_512 = SimConfig::ideal(512);
    t.bench("sim/shift-full/n512/m2 (524k tasks)", || {
        std::hint::black_box(simulate(&s_512, &cfg_512).unwrap());
    });

    // Repeated simulation, 1000 calls at n = 256: alloc-per-call vs one
    // reused buffer vs batched-across-cores. `once` because the workload
    // is already a repetition loop.
    const REPS: usize = 1000;
    let a = t.once("repeat1000/alloc-per-call/n256", || {
        for _ in 0..REPS {
            std::hint::black_box(simulate(&s_256, &cfg_256).unwrap());
        }
    });
    let b = t.once("repeat1000/buffered/n256", || {
        let mut sim = Simulator::new();
        for _ in 0..REPS {
            std::hint::black_box(sim.run(&s_256, &cfg_256).unwrap());
        }
    });
    let group: Vec<Schedule> = vec![s_256.clone(); 8];
    let c = t.once("repeat1000/batched/n256 (8x125, all cores)", || {
        for _ in 0..REPS / group.len() {
            for r in simulate_batch(&group, &cfg_256, 0) {
                std::hint::black_box(r.unwrap());
            }
        }
    });
    println!(
        "  -> buffered {:.2}x, batched {:.2}x over alloc-per-call",
        a.mean_s / b.mean_s,
        a.mean_s / c.mean_s
    );

    // End-to-end tune at equal budget: classic serial loop vs batched
    // parallel candidate scoring. Same winner by construction.
    let spec_tune = ProblemSpec::square(24, 2, MaskSpec::causal());
    let mk_opts = |batch: usize, threads: usize| TuneOptions {
        budget: 240,
        seed: 11,
        sim: SimConfig::ideal(13),
        batch,
        threads,
    };
    let serial = t.once("tune/serial/n24/sm13/budget240", || {
        std::hint::black_box(tune(&spec_tune, &mk_opts(1, 1)).unwrap());
    });
    let batched = t.once("tune/batched/n24/sm13/budget240 (batch 8)", || {
        std::hint::black_box(tune(&spec_tune, &mk_opts(8, 0)).unwrap());
    });
    println!(
        "  -> batched tune {:.2}x over serial at equal budget",
        serial.mean_s / batched.mean_s
    );

    t.finish();
}
