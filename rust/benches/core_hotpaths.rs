//! Bench: core hot paths — simulator event engine, schedule generation,
//! DAG critical path, LPT assignment. Track these numbers across perf PRs.

use dash::dag::{build_schedule_dag, DagBuildOptions};
use dash::schedule::{descending, fa3, lpt::assign_lpt, shift, symmetric_shift, MaskSpec, ProblemSpec};
use dash::sim::{simulate, SimConfig};
use dash::util::BenchTimer;

fn main() {
    let mut t = BenchTimer::new("core");

    // Schedule generation.
    let spec_big = ProblemSpec::square(128, 32, MaskSpec::causal());
    t.bench("gen/fa3/n128/m32", || {
        std::hint::black_box(fa3(&spec_big, true));
    });
    t.bench("gen/symshift/n128/m32", || {
        std::hint::black_box(symmetric_shift(&spec_big));
    });

    // Simulator engine throughput (tasks/sec implied by time).
    let s_causal = fa3(&spec_big, true);
    let cfg = SimConfig::ideal(132);
    t.bench("sim/fa3-causal/n128/m32 (69k tasks)", || {
        std::hint::black_box(simulate(&s_causal, &cfg).unwrap());
    });
    let s_desc = descending(&spec_big);
    t.bench("sim/descending/n128/m32", || {
        std::hint::black_box(simulate(&s_desc, &cfg).unwrap());
    });
    let spec_full = ProblemSpec::square(128, 16, MaskSpec::full());
    let s_shift = shift(&spec_full).unwrap();
    t.bench("sim/shift-full/n128/m16", || {
        std::hint::black_box(simulate(&s_shift, &cfg).unwrap());
    });

    // DAG critical path.
    t.bench("dag/build+cp/fa3/n128/m32", || {
        let d = build_schedule_dag(&s_causal, 128, DagBuildOptions::default());
        std::hint::black_box(d.makespan());
    });

    // LPT assignment.
    t.bench("lpt/assign/n128/m32/132sm", || {
        std::hint::black_box(assign_lpt(&s_causal, 132, 4, 0.5));
    });

    t.finish();
}
