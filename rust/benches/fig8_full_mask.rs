//! Bench: regenerate Figure 8 — full-mask backward throughput for
//! {FA3 baseline, Shift, Descending} across the paper's seqlen sweep.

use dash::bench_harness::{fig8_full_mask, render_table};
use dash::schedule::{Mask, ScheduleKind};
use dash::sim::workload::{run_point, BenchConfig};
use dash::sim::{L2Model, RegisterModel};
use dash::util::BenchTimer;

fn main() {
    let l2 = L2Model::default();
    let reg = RegisterModel::default();

    let rows = fig8_full_mask(l2, &reg);
    println!("== Figure 8: full-mask backward throughput ==");
    println!("{}", render_table(&rows));

    let mut t = BenchTimer::new("fig8");
    for kind in [ScheduleKind::Fa3, ScheduleKind::Shift, ScheduleKind::Descending] {
        let cfg = BenchConfig::paper(8192, 128, Mask::Full);
        t.bench(&format!("sim/{}/seq8192/hd128", kind.name()), || {
            std::hint::black_box(run_point(&cfg, kind, l2, &reg));
        });
    }
    t.finish();
}
