//! Bench: regenerate Figure 8 — full-mask backward throughput for
//! {FA3 baseline, Shift, Descending} across the paper's seqlen sweep.

use dash::bench_harness::{fig8_full_mask, render_table};
use dash::hw::{presets, Machine};
use dash::schedule::{MaskSpec, ScheduleKind};
use dash::sim::workload::{run_point, BenchConfig};
use dash::util::BenchTimer;

fn main() {
    let machine = Machine::real(presets::h800());

    let rows = fig8_full_mask(&machine);
    println!(
        "== Figure 8: full-mask backward throughput ({}) ==",
        machine.profile.name
    );
    println!("{}", render_table(&rows));

    let mut t = BenchTimer::new("fig8");
    for kind in [ScheduleKind::Fa3, ScheduleKind::Shift, ScheduleKind::Descending] {
        let cfg = BenchConfig::paper(8192, 128, MaskSpec::full());
        t.bench(&format!("sim/{}/seq8192/hd128", kind.name()), || {
            std::hint::black_box(run_point(&cfg, kind, &machine));
        });
    }
    t.finish();
}
